//! The fleet front tier: one [`Router`] load-balances requests across N
//! in-process engine replicas.
//!
//! One engine cannot serve heavy traffic; a fleet behind a
//! prefix-cache-aware router can. The replicas share one
//! `Arc<QuantizedModel>` — the packed E8P codes and codebook tables are
//! never duplicated, which is what makes 2-bit weights cheap to
//! replicate ([`crate::serve::NativeEngine::start_replicas`]) — while
//! each replica owns its KV page pool, scheduler thread, and
//! [`Metrics`]. The router implements [`Engine`] itself, so the TCP
//! front-end ([`crate::serve::server`]) serves a fleet through the same
//! code path as a single engine.
//!
//! Routing ([`RoutePolicy`]):
//!
//! * **Prefix affinity** (default): a request carrying an explicit
//!   `prefix_id` — or whose prompt matches a registered prefix by the
//!   same longest-common-token-prefix rule the engine itself uses — is
//!   routed to the replica where that prefix's KV cache is hot, so the
//!   fleet builds each cache once instead of once per replica. Affinity
//!   never starves balance: when the hot replica's in-flight load
//!   exceeds the least-loaded replica's by
//!   [`RouterOptions::spill_margin`] (or hits
//!   [`RouterOptions::max_inflight`]), the request spills to the
//!   least-loaded replica instead. Requests with no usable prefix fall
//!   back to least-loaded.
//! * **Round-robin**: rotate over healthy, non-saturated replicas.
//! * **Least-loaded**: fewest in-flight requests wins (lowest index on
//!   ties).
//!
//! Per-request priority ([`EngineRequest::priority`]) passes through
//! untouched: each replica's submit queue and preemption ordering are
//! already class-aware, so SLO classes work fleet-wide with no router
//! logic beyond delivery.
//!
//! Health: every replica has a watcher thread relaying its responses.
//! A replica that drops a request's answer channel without answering
//! (died — [`crate::serve::NativeEngine::kill`] models this — or
//! panicked), or that exceeds [`RouterOptions::stall_timeout`], is
//! marked unhealthy; its in-flight requests are re-dispatched to
//! healthy replicas (`requests_rerouted`), and it receives no further
//! traffic. A re-routed request restarts from scratch on its new
//! replica — decode is deterministic per request (greedy by
//! construction, sampled via the position-keyed per-request RNG), so
//! the caller still receives exactly the tokens a healthy fleet would
//! have produced, just later.
//!
//! Bounded in-flight: each replica accepts at most
//! [`RouterOptions::max_inflight`] dispatched-but-unanswered requests;
//! beyond that, submissions wait in the router's backlog
//! (priority-ordered like the engines' own queues) and drain as
//! replicas answer.
//!
//! Stats: [`Router::stats_json`] returns the fleet-merged
//! [`Metrics::merged`] view — same field set as a single engine's
//! snapshot — plus `policy`, `replicas_healthy`, and a `replicas`
//! array with each replica's own snapshot (annotated with `replica`,
//! `healthy`, `inflight`).

use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, Weak};
use std::time::Duration;

use crate::generation::paged::PAGE_ROWS;
use crate::util::json::Json;

use super::engine::{Engine, EngineRequest, EngineResponse};
use super::metrics::Metrics;
use super::trace::{TraceEvent, TraceWriter};

/// How the router picks a replica for each request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Prefix-cache affinity with load-based spill; least-loaded for
    /// requests without a usable prefix. The default.
    Prefix,
    /// Rotate over healthy, non-saturated replicas.
    RoundRobin,
    /// Fewest in-flight requests wins (lowest index on ties).
    LeastLoaded,
}

impl RoutePolicy {
    /// Parse a CLI flag value (`serve --route prefix|rr|least-loaded`).
    pub fn parse(s: &str) -> Option<RoutePolicy> {
        match s {
            "prefix" => Some(RoutePolicy::Prefix),
            "rr" | "round-robin" => Some(RoutePolicy::RoundRobin),
            "least-loaded" => Some(RoutePolicy::LeastLoaded),
            _ => None,
        }
    }

    /// The flag spelling, as reported in the stats JSON.
    pub fn label(&self) -> &'static str {
        match self {
            RoutePolicy::Prefix => "prefix",
            RoutePolicy::RoundRobin => "rr",
            RoutePolicy::LeastLoaded => "least-loaded",
        }
    }
}

/// Tunables for [`Router::new`].
#[derive(Clone, Debug)]
pub struct RouterOptions {
    pub policy: RoutePolicy,
    /// Per-replica cap on dispatched-but-unanswered requests; beyond
    /// it, submissions wait in the router's backlog.
    pub max_inflight: usize,
    /// Prefix affinity spills to the least-loaded replica once the hot
    /// replica's in-flight load exceeds the minimum by this many
    /// requests — the affinity-never-starves-balance valve. The
    /// affinity assignment itself is kept: later requests return to the
    /// hot replica once its load subsides.
    pub spill_margin: usize,
    /// When set, a dispatched request not answered within this window
    /// marks its replica stalled (drained and re-routed like a dead
    /// one). `None` (the default) trusts replicas to answer eventually —
    /// a busy replica under deep queueing is not a stalled one, so only
    /// deployments with a latency ceiling should set this.
    pub stall_timeout: Option<Duration>,
    /// Front-shard trace writer ([`crate::serve::trace`]): the router
    /// records `submit`, `reroute`, and router-synthesized failures;
    /// replicas record the rest of the lifecycle through their own
    /// writers. `None` (the default) disables router-side tracing.
    pub tracer: Option<TraceWriter>,
}

impl Default for RouterOptions {
    fn default() -> Self {
        RouterOptions {
            policy: RoutePolicy::Prefix,
            max_inflight: 64,
            spill_margin: 4,
            stall_timeout: None,
            tracer: None,
        }
    }
}

/// One replica as the router sees it: the engine, its dispatch gauge,
/// its health flag, and the channel feeding its watcher thread.
struct Replica {
    engine: Arc<dyn Engine>,
    /// Dispatched-but-unanswered requests (the load signal for
    /// least-loaded routing and the bounded-in-flight cap).
    inflight: AtomicUsize,
    healthy: AtomicBool,
    /// Feeds this replica's watcher thread one [`Pending`] per
    /// dispatched request. `Sender` is not `Sync`, so it sits behind a
    /// mutex; sends never block (the channel is unbounded).
    watch_tx: Mutex<Sender<Pending>>,
}

/// A dispatched request in flight on some replica: what the watcher
/// needs to relay its answer — or to re-route it if the replica dies.
struct Pending {
    req: EngineRequest,
    /// The caller's side of [`Router::submit`].
    outer_tx: Sender<EngineResponse>,
    /// The replica's answer channel for this request.
    inner_rx: Receiver<EngineResponse>,
    /// Re-dispatch count: capped at the replica count, after which the
    /// request fails descriptively instead of bouncing forever.
    hops: usize,
}

struct RouterInner {
    replicas: Vec<Replica>,
    opts: RouterOptions,
    /// Router-level counters only (`requests_rerouted`, plus failures
    /// the router itself synthesizes). Completions are counted by the
    /// replicas, so including this in [`Metrics::merged`] never
    /// double-counts.
    metrics: Arc<Metrics>,
    /// Registered prefixes, mirrored from [`Engine::register_prefix`]
    /// broadcasts, for longest-common-prefix detection at routing time.
    prefixes: Mutex<Vec<(u64, Arc<Vec<u8>>)>>,
    /// prefix id → replica index whose cache is (or will be) hot.
    affinity: Mutex<HashMap<u64, usize>>,
    /// Submissions waiting for a replica to drop below `max_inflight`,
    /// priority-ordered (descending class, FIFO within a class).
    backlog: Mutex<VecDeque<(EngineRequest, Sender<EngineResponse>)>>,
    /// Round-robin cursor.
    rr: AtomicUsize,
    next_id: AtomicU64,
}

/// The fleet front tier; see the module docs. Construct with
/// [`Router::new`], submit through the [`Engine`] impl.
pub struct Router {
    inner: Arc<RouterInner>,
}

impl RouterInner {
    /// Pick a dispatch target for `req` under the configured policy:
    /// only healthy replicas below `max_inflight` are candidates.
    /// `None` means no candidate exists right now — the caller backlogs
    /// the request (watchers drain it as answers free slots).
    fn pick(&self, req: &EngineRequest) -> Option<usize> {
        let ok = |i: usize| {
            let r = &self.replicas[i];
            r.healthy.load(Ordering::Relaxed)
                && r.inflight.load(Ordering::Relaxed) < self.opts.max_inflight
        };
        let least_loaded = || {
            (0..self.replicas.len())
                .filter(|&i| ok(i))
                .min_by_key(|&i| self.replicas[i].inflight.load(Ordering::Relaxed))
        };
        match self.opts.policy {
            RoutePolicy::LeastLoaded => least_loaded(),
            RoutePolicy::RoundRobin => {
                let n = self.replicas.len();
                let start = self.rr.fetch_add(1, Ordering::Relaxed);
                (0..n).map(|k| (start + k) % n).find(|&i| ok(i))
            }
            RoutePolicy::Prefix => {
                let Some(pid) = self.route_prefix_id(req) else {
                    return least_loaded();
                };
                let mut aff = self.affinity.lock().unwrap();
                if let Some(&hot) = aff.get(&pid) {
                    if ok(hot) {
                        // Spill valve: affinity yields to balance when
                        // the hot replica is overloaded relative to the
                        // least-loaded one. The assignment is kept —
                        // the cache is still over there.
                        let hot_load = self.replicas[hot].inflight.load(Ordering::Relaxed);
                        let min_load = least_loaded()
                            .map(|i| self.replicas[i].inflight.load(Ordering::Relaxed))
                            .unwrap_or(hot_load);
                        if hot_load >= min_load + self.opts.spill_margin {
                            return least_loaded();
                        }
                        return Some(hot);
                    }
                    // Hot replica unhealthy or saturated: fall through
                    // and (re)assign if it is truly gone, spill if it is
                    // merely full.
                    if self.replicas[hot].healthy.load(Ordering::Relaxed) {
                        return least_loaded();
                    }
                }
                // First sighting of this prefix (or its replica died):
                // pin it to the least-loaded candidate, whose cache the
                // first request will build.
                let target = least_loaded()?;
                aff.insert(pid, target);
                Some(target)
            }
        }
    }

    /// The prefix id driving affinity for `req`: its explicit
    /// `prefix_id`, or the registered prefix with the longest common
    /// token prefix — accepted under the same meaningful-match
    /// threshold the engine's own admission uses
    /// ([`crate::serve::engine`]), so the router never pins affinity on
    /// a match the replica would decline to fork.
    fn route_prefix_id(&self, req: &EngineRequest) -> Option<u64> {
        let defs = self.prefixes.lock().unwrap();
        let common = |tokens: &[u8]| {
            req.prompt
                .iter()
                .zip(tokens)
                .take_while(|(a, b)| a == b)
                .count()
        };
        let (pid, common, len) = match req.prefix_id {
            Some(want) => defs
                .iter()
                .find(|(id, _)| *id == want)
                .map(|(id, t)| (*id, common(t), t.len()))?,
            None => defs
                .iter()
                .map(|(id, t)| (*id, common(t), t.len()))
                .max_by_key(|&(_, c, _)| c)?,
        };
        (common >= len.min(PAGE_ROWS)).then_some(pid)
    }

    /// Dispatch `req` to replica `to`: submit, bump its in-flight
    /// gauge, and hand the watcher the relay state.
    fn dispatch(
        &self,
        to: usize,
        req: EngineRequest,
        outer_tx: Sender<EngineResponse>,
        hops: usize,
    ) {
        let r = &self.replicas[to];
        r.inflight.fetch_add(1, Ordering::Relaxed);
        let inner_rx = r.engine.submit(req.clone());
        // The watcher only exits once every sender is gone, so this
        // send cannot fail while `self` (holding `watch_tx`) is alive.
        let _ = r.watch_tx.lock().unwrap().send(Pending {
            req,
            outer_tx,
            inner_rx,
            hops,
        });
    }

    /// Queue a submission that no replica can take right now,
    /// priority-ordered like the engines' own queues.
    fn backlog_push(&self, req: EngineRequest, outer_tx: Sender<EngineResponse>) {
        let mut bl = self.backlog.lock().unwrap();
        let at = bl
            .iter()
            .position(|(r, _)| r.priority < req.priority)
            .unwrap_or(bl.len());
        bl.insert(at, (req, outer_tx));
    }

    /// Drain backlogged submissions while a replica will take them
    /// (called by watchers whenever an answer frees a slot).
    fn pump_backlog(&self) {
        loop {
            let item = {
                let mut bl = self.backlog.lock().unwrap();
                match bl.pop_front() {
                    Some(it) => it,
                    None => return,
                }
            };
            match self.pick(&item.0) {
                Some(to) => self.dispatch(to, item.0, item.1, 0),
                None => {
                    // Still no slot: put it back (front — it was the
                    // head of its class) and stop.
                    self.backlog.lock().unwrap().push_front(item);
                    return;
                }
            }
        }
    }

    /// A replica failed a request (died or stalled): mark it unhealthy
    /// and re-dispatch elsewhere. The restarted request reproduces the
    /// exact same tokens — decode is deterministic per request, greedy
    /// and sampled alike — so the caller only sees added latency.
    fn reroute(&self, from: usize, p: Pending) {
        self.replicas[from].healthy.store(false, Ordering::Relaxed);
        self.metrics.record_rerouted();
        // The watcher only observes the dropped channel after the dead
        // replica's die-drain, so this event lands strictly after every
        // event that replica recorded for the request — the merged
        // trace never interleaves the old life with the new one.
        if let Some(w) = &self.opts.tracer {
            w.record(p.req.id, TraceEvent::Reroute { from });
        }
        if p.hops + 1 >= self.replicas.len().max(2) {
            // Every replica has now failed this request once; answer
            // descriptively instead of bouncing forever.
            self.metrics.record_failed();
            let msg = format!(
                "request {} could not be served: every replica failed it \
                 ({} re-routes)",
                p.req.id,
                p.hops + 1
            );
            if let Some(w) = &self.opts.tracer {
                w.finish(p.req.id, TraceEvent::Fail { reason: msg.clone() });
            }
            let _ = p.outer_tx.send(EngineResponse {
                id: p.req.id,
                tokens: Vec::new(),
                latency_ms: 0.0,
                prompt_len: p.req.prompt.len(),
                error: Some(msg),
            });
            return;
        }
        match self.pick(&p.req) {
            Some(to) => self.dispatch(to, p.req, p.outer_tx, p.hops + 1),
            None => {
                if self
                    .replicas
                    .iter()
                    .any(|r| r.healthy.load(Ordering::Relaxed))
                {
                    // Healthy replicas exist but are saturated: wait in
                    // the backlog like any other submission.
                    self.backlog_push(p.req, p.outer_tx);
                } else {
                    self.metrics.record_failed();
                    let msg = "no healthy replica available".to_string();
                    if let Some(w) = &self.opts.tracer {
                        w.finish(p.req.id, TraceEvent::Fail { reason: msg.clone() });
                    }
                    let _ = p.outer_tx.send(EngineResponse {
                        id: p.req.id,
                        tokens: Vec::new(),
                        latency_ms: 0.0,
                        prompt_len: p.req.prompt.len(),
                        error: Some(msg),
                    });
                }
            }
        }
    }
}

/// One replica's watcher loop: relay each dispatched request's answer
/// to its caller, or re-route it when the replica drops the channel
/// (died) or exceeds the stall timeout. Holds only a [`Weak`] to the
/// router, so dropping the [`Router`] closes `rx` and ends the thread.
fn watch_replica(inner: Weak<RouterInner>, idx: usize, rx: Receiver<Pending>) {
    while let Ok(p) = rx.recv() {
        let Some(router) = inner.upgrade() else { return };
        let stall = router.opts.stall_timeout;
        let answer = match stall {
            Some(t) => p.inner_rx.recv_timeout(t).map_err(|_| ()),
            None => p.inner_rx.recv().map_err(|_| ()),
        };
        router.replicas[idx].inflight.fetch_sub(1, Ordering::Relaxed);
        match answer {
            Ok(resp) => {
                // Relay verbatim; the caller may have hung up (that is
                // its business, not an error here).
                let _ = p.outer_tx.send(resp);
            }
            Err(()) => router.reroute(idx, p),
        }
        router.pump_backlog();
        // Drop the strong handle before blocking on the next recv, or
        // the router could never be dropped while a watcher waits.
        drop(router);
    }
}

impl Router {
    /// Build a router over `engines` (typically
    /// [`crate::serve::NativeEngine::start_replicas`]'s output, which
    /// shares one `Arc<QuantizedModel>` across all of them) and spawn
    /// one watcher thread per replica. The watchers exit when the
    /// router is dropped.
    pub fn new(engines: Vec<Arc<dyn Engine>>, opts: RouterOptions) -> Router {
        assert!(!engines.is_empty(), "a router needs at least one replica");
        let mut rxs = Vec::with_capacity(engines.len());
        let replicas: Vec<Replica> = engines
            .into_iter()
            .map(|engine| {
                let (tx, rx) = channel();
                rxs.push(rx);
                Replica {
                    engine,
                    inflight: AtomicUsize::new(0),
                    healthy: AtomicBool::new(true),
                    watch_tx: Mutex::new(tx),
                }
            })
            .collect();
        let inner = Arc::new(RouterInner {
            replicas,
            opts,
            metrics: Arc::new(Metrics::new()),
            prefixes: Mutex::new(Vec::new()),
            affinity: Mutex::new(HashMap::new()),
            backlog: Mutex::new(VecDeque::new()),
            rr: AtomicUsize::new(0),
            next_id: AtomicU64::new(1),
        });
        for (idx, rx) in rxs.into_iter().enumerate() {
            let weak = Arc::downgrade(&inner);
            std::thread::spawn(move || watch_replica(weak, idx, rx));
        }
        Router { inner }
    }

    pub fn fresh_id(&self) -> u64 {
        self.inner.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Replicas currently marked healthy.
    pub fn replicas_healthy(&self) -> usize {
        self.inner
            .replicas
            .iter()
            .filter(|r| r.healthy.load(Ordering::Relaxed))
            .count()
    }

    pub fn replicas_total(&self) -> usize {
        self.inner.replicas.len()
    }
}

impl Engine for Router {
    fn submit(&self, req: EngineRequest) -> Receiver<EngineResponse> {
        let (outer_tx, outer_rx) = channel();
        // The router is the fleet's front: it owns the `submit` event,
        // and the replica a request lands on records the rest.
        if let Some(w) = &self.inner.opts.tracer {
            if w.owns_submit() {
                w.record(req.id, TraceEvent::Submit { class: req.priority });
            }
        }
        match self.inner.pick(&req) {
            Some(to) => self.inner.dispatch(to, req, outer_tx, 0),
            None => {
                if self
                    .inner
                    .replicas
                    .iter()
                    .any(|r| r.healthy.load(Ordering::Relaxed))
                {
                    self.inner.backlog_push(req, outer_tx);
                } else {
                    self.inner.metrics.record_failed();
                    let msg = "no healthy replica available".to_string();
                    if let Some(w) = &self.inner.opts.tracer {
                        w.finish(req.id, TraceEvent::Fail { reason: msg.clone() });
                    }
                    let _ = outer_tx.send(EngineResponse {
                        id: req.id,
                        tokens: Vec::new(),
                        latency_ms: 0.0,
                        prompt_len: req.prompt.len(),
                        error: Some(msg),
                    });
                }
            }
        }
        outer_rx
    }

    /// The router's *own* metrics (re-routes and synthesized failures);
    /// the fleet view is [`Engine::stats_json`].
    fn metrics(&self) -> Arc<Metrics> {
        self.inner.metrics.clone()
    }

    fn stop(&self) {
        for r in &self.inner.replicas {
            r.engine.stop();
        }
    }

    /// Broadcast to every replica (each builds its cache lazily on
    /// first hit — under prefix routing, only the affine replica ever
    /// does) and mirror the tokens for routing-time detection.
    fn register_prefix(&self, id: u64, tokens: Vec<u8>) -> bool {
        let ok = self
            .inner
            .replicas
            .iter()
            .all(|r| r.engine.register_prefix(id, tokens.clone()));
        if ok {
            let tokens = Arc::new(tokens);
            let mut defs = self.inner.prefixes.lock().unwrap();
            match defs.iter_mut().find(|(pid, _)| *pid == id) {
                Some(d) => d.1 = tokens,
                None => defs.push((id, tokens)),
            }
        }
        ok
    }

    /// The fleet-merged lifecycle trace of request `id`: the front
    /// shard's events (submit / reroute / synthesized failures) and
    /// every replica's, sorted by the global sequence stamp.
    fn trace_json(&self, id: u64) -> Json {
        match &self.inner.opts.tracer {
            Some(w) => w.tracer().trace_json(id),
            None => Json::obj(vec![(
                "error",
                Json::str("tracing is not enabled on this backend"),
            )]),
        }
    }

    /// Fleet-merged metrics ([`Metrics::merged`] over the router's own
    /// and every replica's) plus `policy`, `replicas_healthy`, and a
    /// per-replica `replicas` breakdown.
    fn stats_json(&self) -> Json {
        let mut parts = vec![self.inner.metrics.clone()];
        parts.extend(self.inner.replicas.iter().map(|r| r.engine.metrics()));
        let mut merged = Metrics::merged(&parts);
        let rows: Vec<Json> = self
            .inner
            .replicas
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let mut row = r.engine.metrics().snapshot();
                if let Json::Obj(map) = &mut row {
                    map.insert("replica".into(), Json::num(i as f64));
                    map.insert(
                        "healthy".into(),
                        Json::Bool(r.healthy.load(Ordering::Relaxed)),
                    );
                    map.insert(
                        "inflight".into(),
                        Json::num(r.inflight.load(Ordering::Relaxed) as f64),
                    );
                }
                row
            })
            .collect();
        if let Json::Obj(map) = &mut merged {
            map.insert(
                "policy".into(),
                Json::Str(self.inner.opts.policy.label().into()),
            );
            map.insert(
                "replicas_healthy".into(),
                Json::num(self.replicas_healthy() as f64),
            );
            map.insert("replicas".into(), Json::Arr(rows));
        }
        merged
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fake replica for routing-logic tests: answers every request
    /// instantly by echoing its prompt — unless `dead`, in which case
    /// it drops the answer channel (the replica-death signal).
    struct EchoEngine {
        metrics: Arc<Metrics>,
        dead: AtomicBool,
    }

    impl EchoEngine {
        fn new() -> Arc<EchoEngine> {
            Arc::new(EchoEngine {
                metrics: Arc::new(Metrics::new()),
                dead: AtomicBool::new(false),
            })
        }
    }

    impl Engine for EchoEngine {
        fn submit(&self, req: EngineRequest) -> Receiver<EngineResponse> {
            let (tx, rx) = channel();
            if self.dead.load(Ordering::Relaxed) {
                return rx; // dropped sender = disconnect
            }
            self.metrics.record_request(req.prompt.len(), 0.1);
            let _ = tx.send(EngineResponse {
                id: req.id,
                tokens: req.prompt,
                latency_ms: 0.1,
                prompt_len: 0,
                error: None,
            });
            rx
        }
        fn metrics(&self) -> Arc<Metrics> {
            self.metrics.clone()
        }
        fn stop(&self) {}
        fn register_prefix(&self, _id: u64, _tokens: Vec<u8>) -> bool {
            true
        }
    }

    fn req(id: u64, prompt: Vec<u8>, prefix_id: Option<u64>) -> EngineRequest {
        EngineRequest {
            id,
            prompt,
            max_new: 4,
            prefix_id,
            speculate_k: None,
            priority: 0,
            sampling: Default::default(),
        }
    }

    fn fleet(n: usize) -> (Vec<Arc<EchoEngine>>, Vec<Arc<dyn Engine>>) {
        let engines: Vec<Arc<EchoEngine>> = (0..n).map(|_| EchoEngine::new()).collect();
        let dyns = engines
            .iter()
            .map(|e| e.clone() as Arc<dyn Engine>)
            .collect();
        (engines, dyns)
    }

    #[test]
    fn policy_parses_flag_values() {
        assert_eq!(RoutePolicy::parse("prefix"), Some(RoutePolicy::Prefix));
        assert_eq!(RoutePolicy::parse("rr"), Some(RoutePolicy::RoundRobin));
        assert_eq!(
            RoutePolicy::parse("least-loaded"),
            Some(RoutePolicy::LeastLoaded)
        );
        assert_eq!(RoutePolicy::parse("nope"), None);
        assert_eq!(RoutePolicy::parse("prefix").unwrap().label(), "prefix");
    }

    #[test]
    fn round_robin_spreads_requests() {
        let (engines, dyns) = fleet(3);
        let router = Router::new(
            dyns,
            RouterOptions {
                policy: RoutePolicy::RoundRobin,
                ..RouterOptions::default()
            },
        );
        let mut rxs = Vec::new();
        for i in 0..9u64 {
            rxs.push(router.submit(req(i, vec![i as u8], None)));
        }
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert!(r.error.is_none());
        }
        for e in &engines {
            assert_eq!(e.metrics.requests_completed.load(Ordering::Relaxed), 3);
        }
    }

    #[test]
    fn prefix_affinity_concentrates_then_spills() {
        let (engines, dyns) = fleet(2);
        let router = Router::new(
            dyns,
            RouterOptions {
                policy: RoutePolicy::Prefix,
                spill_margin: 100, // effectively never spill
                ..RouterOptions::default()
            },
        );
        let prefix: Vec<u8> = (0..PAGE_ROWS as u8).collect();
        assert!(router.register_prefix(1, prefix.clone()));
        let mut rxs = Vec::new();
        for i in 0..6u64 {
            let mut prompt = prefix.clone();
            prompt.push(100 + i as u8);
            // Mix explicit pins and auto-detection: same affinity.
            let pin = (i % 2 == 0).then_some(1);
            rxs.push(router.submit(req(i, prompt, pin)));
        }
        for rx in rxs {
            assert!(rx.recv_timeout(Duration::from_secs(10)).unwrap().error.is_none());
        }
        let counts: Vec<u64> = engines
            .iter()
            .map(|e| e.metrics.requests_completed.load(Ordering::Relaxed))
            .collect();
        assert!(
            counts.contains(&6) && counts.contains(&0),
            "affinity should concentrate all 6 on one replica, got {counts:?}"
        );
    }

    #[test]
    fn routing_threshold_mirrors_the_engine() {
        // The router pins affinity only on matches the replica would
        // actually fork: whole-prefix (or ≥ one full page) coverage,
        // never a short coincidental overlap.
        let (_engines, dyns) = fleet(2);
        let router = Router::new(dyns, RouterOptions::default());
        let prefix: Vec<u8> = (0..PAGE_ROWS as u8).collect();
        assert!(router.register_prefix(1, prefix.clone()));
        let mut full = prefix.clone();
        full.push(99);
        assert_eq!(router.inner.route_prefix_id(&req(1, full, None)), Some(1));
        // Shares only tokens [0, 1]: below the meaningful-match
        // threshold, so no affinity — balance decides.
        assert_eq!(
            router.inner.route_prefix_id(&req(2, vec![0, 1, 200, 201], None)),
            None
        );
        // An explicit pin on an unknown id is a miss, not an error.
        assert_eq!(
            router.inner.route_prefix_id(&req(3, vec![0, 1], Some(42))),
            None
        );
    }

    #[test]
    fn dead_replica_is_drained_and_requests_rerouted() {
        let (engines, dyns) = fleet(2);
        let router = Router::new(
            dyns,
            RouterOptions {
                policy: RoutePolicy::RoundRobin,
                ..RouterOptions::default()
            },
        );
        engines[0].dead.store(true, Ordering::Relaxed);
        let mut rxs = Vec::new();
        for i in 0..6u64 {
            rxs.push(router.submit(req(i, vec![i as u8, 7], None)));
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert!(r.error.is_none(), "request {i}: {:?}", r.error);
            assert_eq!(r.tokens, vec![i as u8, 7]);
        }
        assert!(
            router
                .metrics()
                .requests_rerouted
                .load(Ordering::Relaxed)
                >= 1
        );
        assert_eq!(router.replicas_healthy(), 1);
        assert_eq!(
            engines[0].metrics.requests_completed.load(Ordering::Relaxed),
            0
        );
        // The fleet stats carry the router extras.
        let stats = router.stats_json();
        assert_eq!(stats.get("replicas_healthy").as_f64(), Some(1.0));
        assert_eq!(
            stats.get("requests_rerouted").as_f64().unwrap() as u64,
            router.metrics().requests_rerouted.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn all_dead_fails_descriptively() {
        let (engines, dyns) = fleet(2);
        let router = Router::new(
            dyns,
            RouterOptions {
                policy: RoutePolicy::LeastLoaded,
                ..RouterOptions::default()
            },
        );
        for e in &engines {
            e.dead.store(true, Ordering::Relaxed);
        }
        let rx = router.submit(req(1, vec![1], None));
        let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        let err = r.error.expect("expected a descriptive failure");
        assert!(
            err.contains("replica"),
            "error should name the fleet condition: {err}"
        );
        // Once both replicas are marked unhealthy, later submits fail
        // immediately without dispatch.
        while router.replicas_healthy() > 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        let r2 = router
            .submit(req(2, vec![2], None))
            .recv_timeout(Duration::from_secs(10))
            .unwrap();
        assert!(r2.error.is_some());
    }
}
