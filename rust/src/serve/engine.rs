//! The inference engine: request queue + continuous batcher + KV slots.
//!
//! Scheduler loop (runs on its own thread):
//!   1. admit queued requests into free KV slots (up to `max_batch`),
//!   2. one decode step across every active sequence (sequence-parallel),
//!   3. retire finished sequences and answer their requests.
//! Requests join/leave at step boundaries — continuous batching.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::generation::{argmax, Generator, KvCache};
use crate::model::Model;
use crate::qmodel::QuantizedModel;

use super::metrics::Metrics;

#[derive(Clone, Debug)]
pub struct EngineRequest {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new: usize,
}

#[derive(Clone, Debug)]
pub struct EngineResponse {
    pub id: u64,
    pub tokens: Vec<u8>,
    pub latency_ms: f64,
    pub prompt_len: usize,
}

/// Trait implemented by serving backends.
pub trait Engine: Send + Sync {
    /// Submit a request; the response arrives on the returned receiver.
    fn submit(&self, req: EngineRequest) -> Receiver<EngineResponse>;
    fn metrics(&self) -> Arc<Metrics>;
    fn stop(&self);
}

struct Active {
    req: EngineRequest,
    tx: Sender<EngineResponse>,
    cache: KvCache,
    generated: Vec<u8>,
    /// Pending prompt tokens not yet prefilled.
    pending_prompt: usize,
    last_logits: Vec<f32>,
    t0: Instant,
}

struct Shared {
    queue: Mutex<Vec<(EngineRequest, Sender<EngineResponse>)>>,
    stop: AtomicBool,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
}

/// Native-backend engine: owns the model (optionally quantized) and a
/// scheduler thread.
pub struct NativeEngine {
    shared: Arc<Shared>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl NativeEngine {
    /// `qm` enables the fused E8P decode path per layer.
    pub fn start(model: Arc<Model>, qm: Option<Arc<QuantizedModel>>, max_batch: usize) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            stop: AtomicBool::new(false),
            metrics: Arc::new(Metrics::new()),
            next_id: AtomicU64::new(1),
        });
        let sh = shared.clone();
        let handle = std::thread::spawn(move || {
            let generator = match &qm {
                Some(q) => Generator::quantized(&model, q),
                None => Generator::dense(&model),
            };
            let mut active: Vec<Active> = Vec::new();
            loop {
                if sh.stop.load(Ordering::Relaxed) && active.is_empty() {
                    break;
                }
                // Admit.
                {
                    let mut q = sh.queue.lock().unwrap();
                    while active.len() < max_batch && !q.is_empty() {
                        let (req, tx) = q.remove(0);
                        let cache = KvCache::new(&model);
                        let pending = req.prompt.len();
                        active.push(Active {
                            req,
                            tx,
                            cache,
                            generated: Vec::new(),
                            pending_prompt: pending,
                            last_logits: Vec::new(),
                            t0: Instant::now(),
                        });
                    }
                }
                if active.is_empty() {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    continue;
                }
                // One decode step per active sequence (prefill consumes one
                // prompt token per step; sequences are independent so the
                // hot matvecs parallelize internally).
                sh.metrics.record_step(active.len());
                for a in active.iter_mut() {
                    let next_tok = if a.pending_prompt > 0 {
                        let idx = a.req.prompt.len() - a.pending_prompt;
                        a.pending_prompt -= 1;
                        a.req.prompt[idx]
                    } else {
                        let t = argmax(&a.last_logits) as u8;
                        a.generated.push(t);
                        t
                    };
                    a.last_logits = generator.decode_one(next_tok, &mut a.cache);
                }
                // Retire.
                let ctx = model.cfg.ctx;
                active.retain_mut(|a| {
                    let done = a.pending_prompt == 0
                        && (a.generated.len() >= a.req.max_new || a.cache.len >= ctx);
                    if done {
                        let resp = EngineResponse {
                            id: a.req.id,
                            tokens: std::mem::take(&mut a.generated),
                            latency_ms: a.t0.elapsed().as_secs_f64() * 1e3,
                            prompt_len: a.req.prompt.len(),
                        };
                        sh.metrics.record_request(resp.tokens.len(), resp.latency_ms);
                        let _ = a.tx.send(resp);
                        false
                    } else {
                        true
                    }
                });
            }
        });
        NativeEngine {
            shared,
            handle: Mutex::new(Some(handle)),
        }
    }

    pub fn fresh_id(&self) -> u64 {
        self.shared.next_id.fetch_add(1, Ordering::Relaxed)
    }

    pub fn join(&self) {
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Engine for NativeEngine {
    fn submit(&self, req: EngineRequest) -> Receiver<EngineResponse> {
        let (tx, rx) = channel();
        self.shared.queue.lock().unwrap().push((req, tx));
        rx
    }

    fn metrics(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }

    fn stop(&self) {
        self.shared.stop.store(true, Ordering::Relaxed);
    }
}

impl Drop for NativeEngine {
    fn drop(&mut self) {
        self.stop();
        self.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests_support::tiny_model;

    #[test]
    fn engine_serves_requests() {
        let model = Arc::new(tiny_model(1));
        let eng = NativeEngine::start(model.clone(), None, 4);
        let mut rxs = Vec::new();
        for i in 0..6 {
            let rx = eng.submit(EngineRequest {
                id: i,
                prompt: vec![1, 2, 3, (i % 60) as u8],
                max_new: 5,
            });
            rxs.push(rx);
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
            assert_eq!(resp.id, i as u64);
            assert_eq!(resp.tokens.len(), 5);
        }
        let m = eng.metrics();
        assert_eq!(m.requests_completed.load(Ordering::Relaxed), 6);
        // With max_batch 4 and 6 requests, some steps must have batched >1.
        assert!(m.mean_batch() > 1.0, "mean batch {}", m.mean_batch());
        eng.stop();
        eng.join();
    }

    #[test]
    fn engine_matches_offline_generation() {
        let model = Arc::new(tiny_model(2));
        let eng = NativeEngine::start(model.clone(), None, 2);
        let prompt = vec![4u8, 8, 15];
        let rx = eng.submit(EngineRequest {
            id: 9,
            prompt: prompt.clone(),
            max_new: 6,
        });
        let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        let offline = Generator::dense(&model).generate(&prompt, 6);
        assert_eq!(resp.tokens, offline);
        eng.stop();
        eng.join();
    }
}
