//! The inference engine: request queue + continuous batcher + paged KV
//! pool.
//!
//! Scheduler loop (runs on its own thread):
//!   1. admit queued requests while the shared KV page pool has a free
//!      page (up to `max_batch`) — admission is bounded by *actual* KV
//!      usage, not worst-case context reservation,
//!   2. reserve this step's KV pages; on exhaustion, preempt the
//!      youngest active sequence (release its pages back to the pool,
//!      requeue its request at the queue front),
//!   3. one *batched* decode step across every active sequence — a single
//!      `Generator::decode_batch_paged` call, so each packed codeword is
//!      decoded once per step and attention runs as one fused blocked
//!      pass over every sequence's page list,
//!   4. extra prefill rounds: sequences still consuming their prompt take
//!      up to [`PREFILL_CHUNK`] tokens per step in batched slices instead
//!      of one token per step,
//!   5. retire finished sequences (pages back to the pool) and answer
//!      their requests.
//! Requests join/leave at step boundaries — continuous batching.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::generation::paged::{pages_per_seq, KvPagePool, PagedKv};
use crate::generation::{argmax, streamed_bytes_for_batch, Generator};
use crate::model::Model;
use crate::qmodel::QuantizedModel;

use super::metrics::Metrics;

/// Prompt tokens a prefilling sequence may consume per scheduler step:
/// a freshly admitted prompt is absorbed in batched slices of this size
/// while decoding sequences still advance every step.
pub const PREFILL_CHUNK: usize = 8;

#[derive(Clone, Debug)]
pub struct EngineRequest {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new: usize,
}

#[derive(Clone, Debug)]
pub struct EngineResponse {
    pub id: u64,
    pub tokens: Vec<u8>,
    pub latency_ms: f64,
    pub prompt_len: usize,
    /// Set when the request was rejected or failed instead of completing
    /// (e.g. prompt longer than the model context, or a sequence that
    /// can never fit in the KV page pool).
    pub error: Option<String>,
}

/// Trait implemented by serving backends.
pub trait Engine: Send + Sync {
    /// Submit a request; the response arrives on the returned receiver.
    fn submit(&self, req: EngineRequest) -> Receiver<EngineResponse>;
    fn metrics(&self) -> Arc<Metrics>;
    fn stop(&self);
}

struct Active {
    req: EngineRequest,
    tx: Sender<EngineResponse>,
    kv: PagedKv,
    generated: Vec<u8>,
    /// Pending prompt tokens not yet prefilled.
    pending_prompt: usize,
    last_logits: Vec<f32>,
    /// Submission time — carried through preemption/requeue so reported
    /// latency covers the request's whole life, queue wait included.
    t0: Instant,
    /// Admission order: preemption evicts the youngest admission first,
    /// so the oldest sequence always makes progress.
    admit_seq: u64,
}

struct Shared {
    queue: Mutex<VecDeque<(EngineRequest, Sender<EngineResponse>, Instant)>>,
    stop: AtomicBool,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    /// Model context length, for submit-time validation.
    ctx: usize,
}

/// Native-backend engine: owns the model (optionally quantized), the
/// shared KV page pool, and a scheduler thread.
pub struct NativeEngine {
    shared: Arc<Shared>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl NativeEngine {
    /// `qm` enables the fused E8P decode path per layer. The KV pool is
    /// sized for the worst case (`max_batch` full-context sequences), so
    /// this constructor never preempts; see
    /// [`NativeEngine::start_with_pool`] to oversubscribe.
    pub fn start(model: Arc<Model>, qm: Option<Arc<QuantizedModel>>, max_batch: usize) -> Self {
        let pages = max_batch.max(1) * pages_per_seq(&model.cfg);
        Self::start_with_pool(model, qm, max_batch, pages)
    }

    /// Start with an explicit KV pool size (in pages of
    /// [`crate::generation::paged::PAGE_ROWS`] token rows; one page holds
    /// every layer's K and V for those rows). Sizing the pool below
    /// `max_batch × paged::pages_per_seq(&cfg)` oversubscribes KV: admission
    /// continues while pages remain, and when an allocation fails the
    /// youngest active sequence is preempted — its pages return to the
    /// pool and its request is requeued (restarted later; greedy decode
    /// makes the retry deterministic).
    pub fn start_with_pool(
        model: Arc<Model>,
        qm: Option<Arc<QuantizedModel>>,
        max_batch: usize,
        pool_pages: usize,
    ) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            stop: AtomicBool::new(false),
            metrics: Arc::new(Metrics::new()),
            next_id: AtomicU64::new(1),
            ctx: model.cfg.ctx,
        });
        let sh = shared.clone();
        let handle = std::thread::spawn(move || {
            let generator = match &qm {
                Some(q) => Generator::quantized(&model, q),
                None => Generator::dense(&model),
            };
            let wb_split = generator.weight_bytes_split();
            let weight_bytes = wb_split.0 + wb_split.1 + wb_split.2;
            let mut pool = KvPagePool::for_model(&model, pool_pages.max(1));
            sh.metrics.set_pool_capacity(pool.pages_total());
            let mut active: Vec<Active> = Vec::new();
            let mut admit_counter: u64 = 0;
            let ctx = model.cfg.ctx;
            loop {
                if sh.stop.load(Ordering::Relaxed) && active.is_empty() {
                    break;
                }
                // Admit (FIFO): pool-aware — a request joins while free
                // pages outnumber this round's admissions (each admission
                // will claim its first page at the first decode round),
                // rather than reserving worst-case `ctx` pages up front.
                // Counting admissions against the free pages avoids
                // admit-then-evict churn when only one page is left.
                {
                    let mut q = sh.queue.lock().unwrap();
                    let mut newly = 0usize;
                    while active.len() < max_batch
                        && (active.is_empty() || pool.pages_free() > newly)
                    {
                        let Some((req, tx, t0)) = q.pop_front() else { break };
                        newly += 1;
                        let pending = req.prompt.len();
                        admit_counter += 1;
                        active.push(Active {
                            req,
                            tx,
                            kv: PagedKv::new(),
                            generated: Vec::new(),
                            pending_prompt: pending,
                            last_logits: Vec::new(),
                            t0,
                            admit_seq: admit_counter,
                        });
                    }
                }
                if active.is_empty() {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    continue;
                }
                // One scheduler step = up to PREFILL_CHUNK batched decode
                // rounds. Round 0 advances every sequence by one token
                // (next prompt token while prefilling, argmax continuation
                // otherwise); later rounds only run sequences still in
                // prefill, so long prompts are consumed in batched slices
                // without re-decoding weights per sequence.
                for round in 0..PREFILL_CHUNK {
                    // Select (active index, token, is_prefill) triples,
                    // in admission order.
                    let mut sel: Vec<(usize, u8, bool)> = Vec::new();
                    for (i, a) in active.iter_mut().enumerate() {
                        if a.pending_prompt > 0 {
                            let idx = a.req.prompt.len() - a.pending_prompt;
                            a.pending_prompt -= 1;
                            sel.push((i, a.req.prompt[idx], true));
                        } else if round == 0 {
                            let t = argmax(&a.last_logits) as u8;
                            a.generated.push(t);
                            sel.push((i, t, false));
                        }
                    }
                    if sel.is_empty() {
                        break;
                    }
                    // Reserve this round's KV pages, preempting under
                    // pressure: when a selected sequence cannot get a
                    // page, the youngest active sequence is evicted (its
                    // pages freed, its request requeued at the front) and
                    // reservation retries. The oldest sequence is never
                    // evicted on behalf of a younger one, so the batch
                    // always makes progress.
                    loop {
                        let mut exhausted = false;
                        for &(i, _, _) in &sel {
                            let need = active[i].kv.len + 1;
                            if !active[i].kv.reserve(&mut pool, need) {
                                exhausted = true;
                                break;
                            }
                        }
                        if !exhausted {
                            break;
                        }
                        // Prefer retiring an already-finished sequence
                        // (one that crossed max_new in round 0 and is
                        // waiting for the post-rounds retire sweep): that
                        // frees its pages AND answers its request —
                        // strictly better than evicting live work.
                        let finished = active.iter().position(|a| {
                            a.pending_prompt == 0
                                && (a.generated.len() >= a.req.max_new || a.kv.len >= ctx)
                        });
                        let victim = match finished {
                            Some(fin) => {
                                let mut a = active.remove(fin);
                                a.kv.release(&mut pool);
                                let resp = EngineResponse {
                                    id: a.req.id,
                                    tokens: std::mem::take(&mut a.generated),
                                    latency_ms: a.t0.elapsed().as_secs_f64() * 1e3,
                                    prompt_len: a.req.prompt.len(),
                                    error: None,
                                };
                                sh.metrics.record_request(resp.tokens.len(), resp.latency_ms);
                                let _ = a.tx.send(resp);
                                fin
                            }
                            None => {
                                if active.len() == 1 {
                                    // Nothing left to evict: the pool
                                    // itself is smaller than this one
                                    // sequence. Fail the request
                                    // descriptively instead of spinning.
                                    let mut a = active.pop().unwrap();
                                    let need = PagedKv::pages_needed(a.kv.len + 1);
                                    a.kv.release(&mut pool);
                                    sh.metrics.record_failed();
                                    let resp = EngineResponse {
                                        id: a.req.id,
                                        tokens: Vec::new(),
                                        latency_ms: a.t0.elapsed().as_secs_f64() * 1e3,
                                        prompt_len: a.req.prompt.len(),
                                        error: Some(format!(
                                            "KV pool too small: sequence needs {need} pages but the pool holds {}",
                                            pool.pages_total()
                                        )),
                                    };
                                    let _ = a.tx.send(resp);
                                    sel.clear();
                                    break;
                                }
                                // Evict the youngest admission: release
                                // its pages, requeue its request at the
                                // queue front.
                                let young = active
                                    .iter()
                                    .enumerate()
                                    .max_by_key(|(_, a)| a.admit_seq)
                                    .map(|(i, _)| i)
                                    .unwrap();
                                let mut a = active.remove(young);
                                a.kv.release(&mut pool);
                                sh.metrics.record_preemption();
                                sh.queue.lock().unwrap().push_front((a.req, a.tx, a.t0));
                                young
                            }
                        };
                        sel.retain(|&(j, _, _)| j != victim);
                        for e in sel.iter_mut() {
                            if e.0 > victim {
                                e.0 -= 1;
                            }
                        }
                        if sel.is_empty() {
                            break;
                        }
                    }
                    if sel.is_empty() {
                        break;
                    }
                    // Count prefill tokens only for sequences that made
                    // it past reservation — evicted sequences' prompt
                    // tokens were never decoded this round (and will be
                    // recounted honestly when the request restarts).
                    let prefill_count = sel.iter().filter(|&&(_, _, p)| p).count();
                    let toks: Vec<u8> = sel.iter().map(|&(_, t, _)| t).collect();
                    let logits = {
                        // Collect the selected sequences' page tables via
                        // one ordered walk (sel indices are increasing).
                        let mut seqs: Vec<&mut PagedKv> = Vec::with_capacity(sel.len());
                        let mut si = 0usize;
                        for (i, a) in active.iter_mut().enumerate() {
                            if si < sel.len() && sel[si].0 == i {
                                seqs.push(&mut a.kv);
                                si += 1;
                            }
                        }
                        generator.decode_batch_paged(&toks, &mut pool, &mut seqs)
                    };
                    let batch = toks.len();
                    {
                        let mut logit_it = logits.into_iter();
                        let mut si = 0usize;
                        for (i, a) in active.iter_mut().enumerate() {
                            if si < sel.len() && sel[si].0 == i {
                                a.last_logits = logit_it.next().unwrap();
                                si += 1;
                            }
                        }
                    }
                    sh.metrics.record_step(batch);
                    sh.metrics.record_prefill(prefill_count);
                    // Decode-once/multiply-many accounting: the batched
                    // kernel amortizes packed codes and dense linear
                    // weights across the round (per-lane lm_head traffic
                    // and per-BATCH_TILE code re-reads included), where a
                    // sequence-at-a-time loop streams everything per lane.
                    sh.metrics.record_decode_bytes(
                        streamed_bytes_for_batch(wb_split, batch),
                        weight_bytes * batch as u64,
                    );
                    sh.metrics.set_pages_in_use(pool.pages_in_use());
                }
                // Retire: release pages back to the pool and answer.
                active.retain_mut(|a| {
                    let done = a.pending_prompt == 0
                        && (a.generated.len() >= a.req.max_new || a.kv.len >= ctx);
                    if done {
                        a.kv.release(&mut pool);
                        let resp = EngineResponse {
                            id: a.req.id,
                            tokens: std::mem::take(&mut a.generated),
                            latency_ms: a.t0.elapsed().as_secs_f64() * 1e3,
                            prompt_len: a.req.prompt.len(),
                            error: None,
                        };
                        sh.metrics.record_request(resp.tokens.len(), resp.latency_ms);
                        let _ = a.tx.send(resp);
                        false
                    } else {
                        true
                    }
                });
                sh.metrics.set_pages_in_use(pool.pages_in_use());
            }
        });
        NativeEngine {
            shared,
            handle: Mutex::new(Some(handle)),
        }
    }

    pub fn fresh_id(&self) -> u64 {
        self.shared.next_id.fetch_add(1, Ordering::Relaxed)
    }

    pub fn join(&self) {
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Engine for NativeEngine {
    fn submit(&self, req: EngineRequest) -> Receiver<EngineResponse> {
        let (tx, rx) = channel();
        // Validate at submit time: a prompt that fills (or overflows) the
        // context can never produce a token, and used to fail only as an
        // assert deep in the generator.
        if req.prompt.len() >= self.shared.ctx {
            self.shared.metrics.record_rejected();
            let _ = tx.send(EngineResponse {
                id: req.id,
                tokens: Vec::new(),
                latency_ms: 0.0,
                prompt_len: req.prompt.len(),
                error: Some(format!(
                    "prompt length {} exceeds model context {} (no room to generate)",
                    req.prompt.len(),
                    self.shared.ctx
                )),
            });
            return rx;
        }
        self.shared
            .queue
            .lock()
            .unwrap()
            .push_back((req, tx, Instant::now()));
        rx
    }

    fn metrics(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }

    fn stop(&self) {
        self.shared.stop.store(true, Ordering::Relaxed);
    }
}

impl Drop for NativeEngine {
    fn drop(&mut self) {
        self.stop();
        self.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests_support::tiny_model;
    use crate::model::{Arch, ModelConfig};

    #[test]
    fn engine_serves_requests() {
        let model = Arc::new(tiny_model(1));
        let eng = NativeEngine::start(model.clone(), None, 4);
        let mut rxs = Vec::new();
        for i in 0..6 {
            let rx = eng.submit(EngineRequest {
                id: i,
                prompt: vec![1, 2, 3, (i % 60) as u8],
                max_new: 5,
            });
            rxs.push(rx);
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
            assert_eq!(resp.id, i as u64);
            assert_eq!(resp.tokens.len(), 5);
            assert!(resp.error.is_none());
        }
        let m = eng.metrics();
        assert_eq!(m.requests_completed.load(Ordering::Relaxed), 6);
        // With max_batch 4 and 6 requests, some steps must have batched >1.
        assert!(m.mean_batch() > 1.0, "mean batch {}", m.mean_batch());
        // The batched kernel amortizes weight traffic across the batch.
        assert!(m.bytes_amortization() > 1.0, "amortization {}", m.bytes_amortization());
        eng.stop();
        eng.join();
        // Worst-case pool: everything fits, nothing is ever preempted,
        // and retirement returns every page (gauge read after join, when
        // the scheduler thread has quiesced).
        assert_eq!(m.preemptions.load(Ordering::Relaxed), 0);
        assert_eq!(m.pages_in_use.load(Ordering::Relaxed), 0);
        assert!(m.peak_pages_in_use.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn engine_matches_offline_generation() {
        let model = Arc::new(tiny_model(2));
        let eng = NativeEngine::start(model.clone(), None, 2);
        let prompt = vec![4u8, 8, 15];
        let rx = eng.submit(EngineRequest {
            id: 9,
            prompt: prompt.clone(),
            max_new: 6,
        });
        let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        let offline = Generator::dense(&model).generate(&prompt, 6);
        assert_eq!(resp.tokens, offline);
        eng.stop();
        eng.join();
    }

    #[test]
    fn chunked_prefill_matches_offline_generation() {
        // A prompt longer than PREFILL_CHUNK is consumed in batched
        // slices across scheduler steps; the generated continuation must
        // be identical to offline token-by-token generation.
        let model = Arc::new(tiny_model(3));
        let eng = NativeEngine::start(model.clone(), None, 3);
        let long_prompt: Vec<u8> = (0..(2 * PREFILL_CHUNK + 3))
            .map(|i| ((i * 11 + 5) % 60) as u8)
            .collect();
        let short_prompt = vec![7u8, 2];
        let rx_long = eng.submit(EngineRequest {
            id: 1,
            prompt: long_prompt.clone(),
            max_new: 6,
        });
        let rx_short = eng.submit(EngineRequest {
            id: 2,
            prompt: short_prompt.clone(),
            max_new: 6,
        });
        let gen = Generator::dense(&model);
        let resp_long = rx_long
            .recv_timeout(std::time::Duration::from_secs(30))
            .unwrap();
        let resp_short = rx_short
            .recv_timeout(std::time::Duration::from_secs(30))
            .unwrap();
        assert_eq!(resp_long.tokens, gen.generate(&long_prompt, 6));
        assert_eq!(resp_short.tokens, gen.generate(&short_prompt, 6));
        // Prefill accounting saw the long prompt.
        let m = eng.metrics();
        let prefill = m.prefill_tokens.load(Ordering::Relaxed) as usize;
        assert_eq!(prefill, long_prompt.len() + short_prompt.len());
        eng.stop();
        eng.join();
    }

    #[test]
    fn rejects_overlong_prompt_at_submit() {
        let model = Arc::new(tiny_model(4));
        let ctx = model.cfg.ctx;
        let eng = NativeEngine::start(model.clone(), None, 2);
        // Exactly ctx (no room to generate) and well past ctx: both are
        // answered immediately with a descriptive error, never enqueued.
        for plen in [ctx, ctx + 9] {
            let rx = eng.submit(EngineRequest {
                id: 77,
                prompt: vec![1u8; plen],
                max_new: 4,
            });
            let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
            assert!(resp.tokens.is_empty());
            assert_eq!(resp.prompt_len, plen);
            let err = resp.error.expect("expected a rejection error");
            assert!(err.contains("exceeds model context"), "{err}");
        }
        assert_eq!(eng.metrics().requests_rejected.load(Ordering::Relaxed), 2);
        // A fitting prompt still goes through on the same engine.
        let rx = eng.submit(EngineRequest {
            id: 78,
            prompt: vec![1, 2, 3],
            max_new: 2,
        });
        let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert!(resp.error.is_none());
        assert_eq!(resp.tokens.len(), 2);
        eng.stop();
        eng.join();
    }

    /// ctx = 64 = two KV pages per worst-case sequence, so a small pool
    /// creates real paging pressure (tiny_model's ctx is a single page).
    fn two_page_model(seed: u64) -> Model {
        let cfg = ModelConfig {
            name: "tiny2p".into(),
            d_model: 32,
            n_layers: 1,
            n_heads: 2,
            d_ff: 64,
            vocab: 64,
            ctx: 64,
            arch: Arch::Llama,
            n_experts: 2,
        };
        Model::random(cfg, seed)
    }

    #[test]
    fn preemption_requeues_and_completes() {
        // Pool of 2 pages, but each finished sequence spans 2 pages and
        // up to two run concurrently: allocations must fail, the youngest
        // sequence must be preempted (pages released, request requeued),
        // and every request must still complete with the exact offline
        // greedy continuation.
        let model = Arc::new(two_page_model(5));
        assert_eq!(pages_per_seq(&model.cfg), 2);
        let eng = NativeEngine::start_with_pool(model.clone(), None, 2, 2);
        let gen = Generator::dense(&model);
        let max_new = 40; // 2 + 40 rows = 2 pages per sequence
        let mut rxs = Vec::new();
        let mut prompts = Vec::new();
        for i in 0..3u64 {
            let prompt = vec![(3 + 5 * i) as u8, (7 + i) as u8];
            rxs.push(eng.submit(EngineRequest {
                id: i,
                prompt: prompt.clone(),
                max_new,
            }));
            prompts.push(prompt);
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
            assert!(resp.error.is_none(), "request {i}: {:?}", resp.error);
            assert_eq!(
                resp.tokens,
                gen.generate(&prompts[i], max_new),
                "request {i} diverged after preemption/requeue"
            );
        }
        let m = eng.metrics();
        eng.stop();
        eng.join();
        assert!(
            m.preemptions.load(Ordering::Relaxed) > 0,
            "pool pressure never triggered a preemption"
        );
        assert_eq!(m.pages_in_use.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn paged_admission_beats_worst_case_reservation() {
        // Pool of 3 pages with 2-page worst-case sequences: contiguous
        // worst-case-ctx reservation could admit only one sequence, but
        // short requests touch a single page each, so the paged engine
        // runs several concurrently.
        let model = Arc::new(two_page_model(6));
        let pool_pages = 3;
        let worst_case_admissible = pool_pages / pages_per_seq(&model.cfg);
        assert_eq!(worst_case_admissible, 1);
        let eng = NativeEngine::start_with_pool(model.clone(), None, 4, pool_pages);
        let mut rxs = Vec::new();
        for i in 0..4u64 {
            rxs.push(eng.submit(EngineRequest {
                id: i,
                prompt: vec![2, (i + 1) as u8],
                max_new: 20, // 22 rows: one page per sequence
            }));
        }
        for rx in rxs {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
            assert!(resp.error.is_none());
            assert_eq!(resp.tokens.len(), 20);
        }
        let m = eng.metrics();
        let peak = m.peak_batch.load(Ordering::Relaxed) as usize;
        assert!(
            peak > worst_case_admissible,
            "paged admission reached {peak}, no better than worst-case {worst_case_admissible}"
        );
        eng.stop();
        eng.join();
    }

    #[test]
    fn oversized_sequence_fails_descriptively() {
        // A pool smaller than a single sequence cannot ever serve it:
        // the engine must answer with an error instead of spinning.
        let model = Arc::new(two_page_model(7));
        let eng = NativeEngine::start_with_pool(model.clone(), None, 2, 1);
        let rx = eng.submit(EngineRequest {
            id: 1,
            prompt: vec![1, 2, 3],
            max_new: 60, // needs 2 pages; pool holds 1
        });
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        let err = resp.error.expect("expected pool-too-small error");
        assert!(err.contains("KV pool too small"), "{err}");
        let m = eng.metrics();
        eng.stop();
        eng.join();
        // Mid-flight failure, not a submit-time rejection.
        assert_eq!(m.requests_failed.load(Ordering::Relaxed), 1);
        assert_eq!(m.requests_rejected.load(Ordering::Relaxed), 0);
    }
}
