//! The inference engine: request queue + continuous batcher + paged KV
//! pool with copy-on-write prompt-prefix sharing.
//!
//! Scheduler loop (runs on its own thread):
//!   1. admit queued requests while the shared KV page pool has a free
//!      page (up to `max_batch`) — admission is bounded by *actual* KV
//!      usage, not worst-case context reservation. If a request's prompt
//!      starts with a registered prefix ([`Engine::register_prefix`],
//!      matched by longest common token prefix or named explicitly via
//!      [`EngineRequest::prefix_id`]), the scheduler *forks* the cached
//!      prefix — sharing its KV pages and skipping its prefill compute —
//!      instead of re-prefilling it,
//!   2. reserve this step's KV pages (cloning any shared page the step
//!      would write into — copy-on-write); on exhaustion, preempt the
//!      youngest active sequence (release its pages back to the pool,
//!      requeue its request at the queue front),
//!   3. one *batched* decode step across every active sequence — a single
//!      `Generator::decode_batch_paged` call, so each packed codeword is
//!      decoded once per step and attention runs as one cross-sequence
//!      fused block walk ([`crate::generation::paged::fused_batch_attention`]):
//!      page tables may alias the shared prefix pages, and sequences are
//!      grouped by physical K/V block so an aliased block is loaded once
//!      per step for every fork reading it, not once per sequence —
//!      logits are bit-exact either way,
//!   4. extra prefill rounds: sequences still consuming their prompt take
//!      up to [`PREFILL_CHUNK`] tokens per step in batched slices instead
//!      of one token per step,
//!   5. a speculative phase: sequences with `speculate_k > 0` advance
//!      through one draft/verify round per step instead of the plain
//!      round-0 continuation — the RVQ base-stage draft proposes up to k
//!      tokens against its own KV (pages from the same pool), the target
//!      verifies all k + 1 positions in one chunked batched step
//!      ([`crate::generation::speculative::spec_round_paged`]), and both
//!      KVs truncate back to the last accepted token. The coupled
//!      accept rule keeps responses bit-identical to plain decode —
//!      greedy *and* sampled,
//!   6. retire finished sequences (pages back to the pool) and answer
//!      their requests.
//! Requests join/leave at step boundaries — continuous batching.
//!
//! Every decode, prefill, and verify step above is a batched
//! `Generator::decode_*` call, so the scheduler inherits the persistent
//! worker pool ([`crate::util::threadpool`]) transparently: the matmul
//! row tiles and the fused attention lane groups of each step fan out
//! across `QUIPSHARP_THREADS` cores below this layer, bit-exactly, with
//! no engine-level threading logic.
//!
//! Preemption ordering invariants: the victim is the youngest admission
//! of the *lowest priority class* present ([`EngineRequest::priority`],
//! higher = more urgent) — within a class the oldest sequence keeps
//! making progress, and the highest-priority oldest sequence is never
//! evicted at all, so the batch never livelocks. An already-finished
//! sequence is retired in preference to evicting live work, and
//! eviction releases only the victim's *own* page references — pages
//! shared with the prefix cache or sibling forks survive until their
//! last holder lets go, so preempting a forked sequence can never
//! corrupt another sequence's KV. A preempted forked request re-forks
//! on re-admission, making its restart cheap (only the unshared rows
//! are re-prefilled). The submit queue is priority-ordered the same
//! way: a request enters behind every queued request of its class or
//! higher (FIFO within a class), and a preempted request re-enters at
//! the *front* of its class. Priorities never change tokens — decode is
//! deterministic per request regardless of schedule, greedy by
//! construction and sampled via the position-keyed per-request RNG
//! ([`crate::generation::sampling`]) — they only reorder who waits.
//!
//! The prefix cache itself is built lazily by the scheduler (one
//! sequential prefill, the first time a registered prefix meaningfully
//! matches) and its pages stay pinned — refcounted like any other
//! holder — while the cache is warm, so a hot system prompt is paid for
//! once. Under pool pressure the pin is not forever: *cold* caches
//! (every page at refcount 1, i.e. no live fork reads them) are
//! unpinned in LRU order — before any cache build that lacks headroom,
//! and before any live sequence is preempted (`prefix_evictions`
//! metric); a later hit simply rebuilds. Two deliberate trade-offs: the
//! build runs inside the admission step, so in-flight sequences pause
//! for one prefix prefill (once per build — amortized across every
//! later hit), and a build is refused unless the pool keeps at least
//! one free page of headroom beyond the cache, so pinning can never
//! consume the last pages the forked sequences themselves need.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::generation::paged::{
    pages_per_seq, KvPagePool, KvQuantSpec, PageExport, PagedKv, PAGE_ROWS,
};
use crate::generation::sampling::{next_token, SamplingParams};
use crate::generation::speculative::{effective_k, spec_round_paged, SpecLane, SpecStats};
use crate::generation::{streamed_bytes_for_batch, AttnMode, Generator};
use crate::model::qlinear::codewords_decoded;
use crate::model::Model;
use crate::qmodel::QuantizedModel;

use super::metrics::Metrics;
use super::trace::{TraceEvent, TraceWriter};

/// Prompt tokens a prefilling sequence may consume per scheduler step:
/// a freshly admitted prompt is absorbed in batched slices of this size
/// while decoding sequences still advance every step.
pub const PREFILL_CHUNK: usize = 8;

#[derive(Clone, Debug)]
pub struct EngineRequest {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new: usize,
    /// Fork the prefix registered under this id (when the prompt starts
    /// with its tokens) instead of letting the engine auto-detect the
    /// longest matching registered prefix. `None` = auto-detect; an
    /// unknown id is simply a miss, never an error.
    pub prefix_id: Option<u64>,
    /// Draft tokens per self-speculative round for this request
    /// (`Some(0)` forces plain decode; `None` uses the engine's default,
    /// [`EngineOptions::speculate_k`]). Speculation never changes the
    /// response — greedy accept/reject keeps it bit-identical to plain
    /// decode — only its latency/throughput (TCP field: `speculate`).
    pub speculate_k: Option<usize>,
    /// SLO class, higher = more urgent (default 0). Orders the submit
    /// queue (FIFO within a class) and inverts into preemption: under
    /// pool pressure the victim is the youngest admission of the lowest
    /// class present. Never changes a request's tokens, only who waits
    /// (TCP field: `priority`).
    pub priority: u8,
    /// Stochastic-decode controls (TCP fields: `temperature` / `top_k` /
    /// `top_p` / `seed`; the default is greedy). Sampled tokens are a
    /// pure function of `(seed, absolute position, logits)`, so the
    /// response stream is identical on any replica, batch composition,
    /// thread count, speculation depth, or preempt/spill/restore
    /// history.
    pub sampling: SamplingParams,
}

#[derive(Clone, Debug)]
pub struct EngineResponse {
    pub id: u64,
    pub tokens: Vec<u8>,
    pub latency_ms: f64,
    pub prompt_len: usize,
    /// Set when the request was rejected or failed instead of completing
    /// (e.g. prompt longer than the model context, or a sequence that
    /// can never fit in the KV page pool).
    pub error: Option<String>,
}

/// Trait implemented by serving backends.
pub trait Engine: Send + Sync {
    /// Submit a request; the response arrives on the returned receiver.
    fn submit(&self, req: EngineRequest) -> Receiver<EngineResponse>;
    fn metrics(&self) -> Arc<Metrics>;
    fn stop(&self);
    /// Register a reusable prompt prefix (e.g. a system prompt) under
    /// `id`. Requests whose prompts start with these tokens can then be
    /// admitted by sharing the cached prefix's KV pages (copy-on-write)
    /// instead of re-prefilling them. Re-registering an id replaces its
    /// tokens. Returns `false` when the backend does not support prefix
    /// sharing or the tokens are unusable (empty, or ≥ model context).
    fn register_prefix(&self, id: u64, tokens: Vec<u8>) -> bool {
        let _ = (id, tokens);
        false
    }
    /// The stats-API JSON for this backend. A single engine snapshots
    /// its own [`Metrics`]; a fleet front ([`crate::serve::router`])
    /// overrides this with the merged view plus per-replica breakdown,
    /// so the TCP `stats` command serves either shape through one call.
    fn stats_json(&self) -> crate::util::json::Json {
        self.metrics().snapshot()
    }
    /// The merged lifecycle trace of request `id`
    /// ([`crate::serve::trace::Tracer::trace_json`]) — the TCP `trace`
    /// command. Backends without a tracer configured answer with an
    /// `error` object instead of failing the connection.
    fn trace_json(&self, id: u64) -> crate::util::json::Json {
        let _ = id;
        crate::util::json::Json::obj(vec![(
            "error",
            crate::util::json::Json::str("tracing is not enabled on this backend"),
        )])
    }
}

/// A registered, reusable prompt prefix (e.g. a system prompt).
struct PrefixDef {
    id: u64,
    tokens: Arc<Vec<u8>>,
}

/// Scheduler-side cache for one registered prefix: its KV rows,
/// prefilled once into pooled pages that forks then share, plus the
/// logits after its final token (used when a prompt *equals* the prefix,
/// so even the first generated token needs no prefill).
struct PrefixCache {
    tokens: Arc<Vec<u8>>,
    kv: PagedKv,
    last_logits: Vec<f32>,
    /// Scheduler clock value of the last fork off this cache (or its
    /// build) — the LRU key for cold-prefix eviction.
    last_used: u64,
}

/// A preempted sequence parked outside the pool: its pages exported
/// verbatim (cold pages keep their codes, hot tail pages keep raw f32
/// rows), so restoring reproduces the exact KV state and skips the
/// re-prefill a plain requeue would pay. The draft KV is *not* spilled —
/// it is cheap to rebuild from the true stream, so it is released and
/// `draft_pending` re-seeded on restore.
struct SpilledSeq {
    req: EngineRequest,
    tx: Sender<EngineResponse>,
    generated: Vec<u8>,
    pending_prompt: usize,
    last_logits: Vec<f32>,
    spec_k: usize,
    exports: Vec<PageExport>,
    kv_len: usize,
    t0: Instant,
    /// Original admission and first-token stamps ride along: the spilled
    /// stream survives the round trip, so the queue/ttft latency split
    /// keeps measuring to the admission that produced it.
    admitted_at: Instant,
    first_token_at: Option<Instant>,
}

/// An unpinned prefix cache parked in the arena: re-imported on the next
/// hit instead of re-prefilled.
struct SpilledPrefix {
    tokens: Arc<Vec<u8>>,
    exports: Vec<PageExport>,
    kv_len: usize,
    last_logits: Vec<f32>,
}

/// Host-side arena for KV pages exported from the pool. Only populated
/// when KV quantization is on (`enabled`): with fp32 KV, preemption
/// keeps the historical requeue-and-restart path byte-for-byte, so the
/// quant-off engine behaves exactly as before this tier existed.
struct SpillArena {
    enabled: bool,
    seqs: Vec<SpilledSeq>,
    prefixes: HashMap<u64, SpilledPrefix>,
}

impl SpillArena {
    fn new(enabled: bool) -> Self {
        SpillArena {
            enabled,
            seqs: Vec::new(),
            prefixes: HashMap::new(),
        }
    }

    /// Pages currently parked here (sequences + prefixes) — the
    /// `kv_spilled_pages` gauge.
    fn pages(&self) -> usize {
        self.seqs.iter().map(|s| s.exports.len()).sum::<usize>()
            + self.prefixes.values().map(|p| p.exports.len()).sum::<usize>()
    }
}

/// Evict the least-recently-used *cold* prefix cache — one whose pages
/// no live sequence references any more (every page at refcount 1, so
/// releasing frees them all) — returning whether anything was evicted.
/// `exclude` protects a cache mid-(re)build. Hot caches (any page still
/// shared with an active fork) are never touched: releasing them would
/// free nothing now and forfeit pages live sequences still read. With
/// the spill arena enabled the victim's pages are exported there (the
/// next hit restores by import); otherwise they are simply released and
/// a later hit rebuilds by prefill.
fn evict_cold_prefix(
    cache: &mut HashMap<u64, PrefixCache>,
    pool: &mut KvPagePool,
    arena: &mut SpillArena,
    metrics: &Metrics,
    exclude: Option<u64>,
) -> bool {
    let victim = cache
        .iter()
        .filter(|(pid, c)| {
            Some(**pid) != exclude && c.kv.pages.iter().all(|&p| pool.refcount(p) == 1)
        })
        .min_by_key(|(_, c)| c.last_used)
        .map(|(pid, _)| *pid);
    match victim {
        Some(pid) => {
            let mut old = cache.remove(&pid).unwrap();
            if arena.enabled {
                let kv_len = old.kv.len;
                let exports = old.kv.spill(pool);
                arena.prefixes.insert(
                    pid,
                    SpilledPrefix {
                        tokens: old.tokens,
                        exports,
                        kv_len,
                        last_logits: old.last_logits,
                    },
                );
            } else {
                old.kv.release(pool);
            }
            metrics.record_prefix_eviction();
            true
        }
        None => false,
    }
}

/// Longest common prefix of two token streams.
fn common_prefix_len(a: &[u8], b: &[u8]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

/// Try to admit `req` by forking a registered prompt prefix into `kv`.
///
/// Picks the registered prefix with the longest common token prefix
/// against the request's prompt (or the one named by `req.prefix_id`),
/// lazily prefills its KV rows once into pooled pages, and forks the
/// common rows into `kv` by sharing those pages. Returns the forked row
/// count and, when the whole prompt was covered, the cached logits of
/// its final token. `None` is a miss — nothing registered, nothing
/// matching, or the cache not buildable under current pool pressure —
/// and the caller prefills normally.
fn try_fork_prefix(
    req: &EngineRequest,
    sh: &Shared,
    generator: &Generator,
    pool: &mut KvPagePool,
    cache: &mut HashMap<u64, PrefixCache>,
    arena: &mut SpillArena,
    kv: &mut PagedKv,
    clock: u64,
) -> Option<(usize, Option<Vec<f32>>)> {
    let (pid, common, tokens) = {
        let defs = sh.prefixes.lock().unwrap();
        let score =
            |d: &PrefixDef| (d.id, common_prefix_len(&req.prompt, &d.tokens), d.tokens.clone());
        match req.prefix_id {
            Some(want) => defs.iter().find(|d| d.id == want).map(score),
            None => defs.iter().map(score).max_by_key(|&(_, common, _)| common),
        }?
    };
    // Only a *meaningful* match justifies building (and pinning) the
    // cache: the prompt must contain the whole registered prefix, or at
    // least one fully shareable page of it. A shorter coincidental
    // overlap would pay the full cache prefill to share nothing but a
    // partial tail page that the very next write clones back.
    if common < tokens.len().min(PAGE_ROWS) {
        return None;
    }
    // (Re)build the cache entry if missing or re-registered since.
    let stale = match cache.get(&pid) {
        Some(c) => !Arc::ptr_eq(&c.tokens, &tokens),
        None => true,
    };
    if stale {
        if let Some(mut old) = cache.remove(&pid) {
            old.kv.release(pool);
        }
        // A spilled copy of this cache restores by import — no prefill
        // compute at all — provided its tokens are still current and the
        // pool has room. A capacity-miss keeps it parked for a later
        // hit; a re-registered prefix invalidates the spilled copy.
        if let Some(sp) = arena.prefixes.remove(&pid) {
            if Arc::ptr_eq(&sp.tokens, &tokens) {
                let mut sp = sp;
                let mut pkv = PagedKv::new();
                if pkv.restore(pool, &mut sp.exports, sp.kv_len) {
                    cache.insert(
                        pid,
                        PrefixCache {
                            tokens: sp.tokens,
                            kv: pkv,
                            last_logits: sp.last_logits,
                            last_used: clock,
                        },
                    );
                } else {
                    arena.prefixes.insert(pid, sp);
                }
            }
        }
    }
    if !cache.contains_key(&pid) {
        // Check capacity before spending any prefill compute: the
        // scheduler is single-threaded, so free pages now means the
        // whole build succeeds. Demand a page of headroom beyond the
        // cache — its pages stay pinned while warm, so building into
        // the last free pages would leave nothing for the sequences the
        // cache exists to serve. Under pressure, unpin cold cached
        // prefixes (LRU order) — but only after confirming free +
        // evictable pages actually cover the build, so an infeasible
        // build never destroys caches for nothing. Too tight → fall
        // back to a normal prefill; a later admission retries once
        // pages free.
        let build_need = PagedKv::pages_needed(tokens.len()) + 1;
        if build_need > pool.pages_free() {
            let evictable: usize = cache
                .iter()
                .filter(|(other, c)| {
                    **other != pid && c.kv.pages.iter().all(|&p| pool.refcount(p) == 1)
                })
                .map(|(_, c)| c.kv.pages.len())
                .sum();
            if build_need > pool.pages_free() + evictable {
                return None;
            }
            while build_need > pool.pages_free() {
                if !evict_cold_prefix(cache, pool, arena, &sh.metrics, Some(pid)) {
                    return None;
                }
            }
        }
        let mut pkv = PagedKv::new();
        let mut logits = Vec::new();
        for &t in tokens.iter() {
            if !pkv.reserve(pool, pkv.len + 1) {
                pkv.release(pool);
                return None;
            }
            logits = generator
                .decode_batch_paged(&[t], pool, &mut [&mut pkv])
                .pop()
                .unwrap();
        }
        sh.metrics.record_prefill(tokens.len());
        let entry = PrefixCache {
            tokens: tokens.clone(),
            kv: pkv,
            last_logits: logits,
            last_used: clock,
        };
        cache.insert(pid, entry);
    }
    let entry = cache.get_mut(&pid)?;
    entry.last_used = clock;
    let entry = &*entry;
    // The fork must leave at least one prompt token to decode — unless
    // the prompt *is* the whole prefix, whose final logits are cached.
    let whole = common == req.prompt.len() && common == entry.tokens.len();
    let fork_rows = if whole {
        common
    } else {
        common.min(req.prompt.len() - 1)
    };
    if fork_rows == 0 {
        return None;
    }
    kv.fork_prefix(pool, &entry.kv, fork_rows);
    let logits = whole.then(|| entry.last_logits.clone());
    Some((fork_rows, logits))
}

/// What [`free_pages`] did to relieve pool pressure.
enum Freed {
    /// `active[i]` was removed — retired (finished work answered),
    /// preempted (requeued), or failed (answered with an error). The
    /// caller must drop the index from any selection and shift larger
    /// indices down.
    Removed(usize),
    /// `active[i]` was preempted into the spill arena (it is now
    /// `arena.seqs.last()`). Index handling as for [`Freed::Removed`];
    /// additionally, a caller that advanced the victim's cursor for a
    /// decode that now never runs must undo that advance on the parked
    /// copy — the spilled sequence resumes *exactly* where its last
    /// completed decode left it.
    Spilled(usize),
    /// A cold prefix cache was unpinned; `active` is untouched.
    PrefixEvicted,
}

/// Record a completed request: the whole-request latency plus its
/// queue/ttft/decode split (from the admission and first-token stamps
/// the sequence carried), and the terminal `finish` trace event — which
/// also exports the trace line when a JSONL sink is configured.
fn retire_metrics(sh: &Shared, a: &Active, tokens: usize, latency_ms: f64) {
    let queue_ms = a.admitted_at.duration_since(a.t0).as_secs_f64() * 1e3;
    let (ttft_ms, decode_ms) = match a.first_token_at {
        Some(ft) => (
            Some(ft.duration_since(a.t0).as_secs_f64() * 1e3),
            Some(ft.elapsed().as_secs_f64() * 1e3),
        ),
        None => (None, None),
    };
    sh.metrics
        .record_request_timed(tokens, latency_ms, queue_ms, ttft_ms, decode_ms);
    if let Some(w) = &sh.tracer {
        w.finish(a.req.id, TraceEvent::Finish { tokens });
    }
}

/// Relieve KV pool pressure, preferring the cheapest remedy first:
/// retire an already-finished sequence (frees its pages *and* answers
/// its request), unpin the LRU cold prefix cache (frees pages at the
/// cost of a future rebuild), preempt the youngest admission — with the
/// spill arena enabled its pages are exported host-side and re-imported
/// on re-admission (no re-prefill); otherwise they are released and the
/// request requeued at the queue front — or, when only one sequence
/// remains and nothing else can free, fail that request descriptively
/// instead of spinning.
fn free_pages(
    active: &mut Vec<Active>,
    pool: &mut KvPagePool,
    sh: &Shared,
    prefix_cache: &mut HashMap<u64, PrefixCache>,
    arena: &mut SpillArena,
    ctx: usize,
) -> Freed {
    // An already-finished sequence (one that crossed max_new in round 0
    // and is waiting for the post-rounds retire sweep): retiring it is
    // strictly better than evicting live work.
    let finished = active.iter().position(|a| {
        a.pending_prompt == 0 && (a.generated.len() >= a.req.max_new || a.kv.len >= ctx)
    });
    if let Some(fin) = finished {
        let mut a = active.remove(fin);
        a.kv.release(pool);
        a.draft_kv.release(pool);
        let resp = EngineResponse {
            id: a.req.id,
            tokens: std::mem::take(&mut a.generated),
            latency_ms: a.t0.elapsed().as_secs_f64() * 1e3,
            prompt_len: a.req.prompt.len(),
            error: None,
        };
        retire_metrics(sh, &a, resp.tokens.len(), resp.latency_ms);
        let _ = a.tx.send(resp);
        return Freed::Removed(fin);
    }
    // Cold prefix caches are passive pinned pages: unpin before
    // touching live sequences.
    if evict_cold_prefix(prefix_cache, pool, arena, &sh.metrics, None) {
        return Freed::PrefixEvicted;
    }
    if active.len() == 1 {
        // Nothing left to evict: the pool itself is smaller than this
        // one sequence. Fail the request descriptively instead of
        // spinning.
        let mut a = active.pop().unwrap();
        let need = PagedKv::pages_needed(a.kv.len + 1);
        // A speculating sequence also pins a draft KV; name that demand
        // so the failure isn't misread as the target alone overflowing
        // an apparently ample pool.
        let draft_need = if a.spec_k > 0 {
            PagedKv::pages_needed(a.draft_kv.len + a.draft_pending.len() + 1)
        } else {
            0
        };
        a.kv.release(pool);
        a.draft_kv.release(pool);
        sh.metrics.record_failed();
        // Pages pinned by resident prefix caches shrink the effective
        // pool; say so instead of misdiagnosing the pool as undersized.
        let pinned: usize = prefix_cache.values().map(|c| c.kv.pages.len()).sum();
        let mut msg = format!(
            "KV pool too small: sequence needs {need} pages{} but the pool holds {}",
            if draft_need > 0 {
                format!(" (+{draft_need} for its speculative draft KV)")
            } else {
                String::new()
            },
            pool.pages_total()
        );
        if pinned > 0 {
            msg.push_str(&format!(" ({pinned} pinned by prefix caches)"));
        }
        if let Some(w) = &sh.tracer {
            w.finish(a.req.id, TraceEvent::Fail { reason: msg.clone() });
        }
        let resp = EngineResponse {
            id: a.req.id,
            tokens: Vec::new(),
            latency_ms: a.t0.elapsed().as_secs_f64() * 1e3,
            prompt_len: a.req.prompt.len(),
            error: Some(msg),
        };
        let _ = a.tx.send(resp);
        return Freed::Removed(0);
    }
    // Evict the youngest admission of the lowest priority class
    // present: release its pages (draft included). Within a class the
    // oldest sequence is never evicted on behalf of a younger one, and
    // the highest-priority oldest sequence is never evicted at all, so
    // the batch always makes progress. With the spill arena enabled the
    // victim's KV pages move host-side (generated tokens and logits
    // ride along, so re-admission resumes exactly where it stopped);
    // otherwise its request is requeued at the front of its priority
    // class and restarts from prefill.
    let young = active
        .iter()
        .enumerate()
        .max_by_key(|(_, a)| (std::cmp::Reverse(a.req.priority), a.admit_seq))
        .map(|(i, _)| i)
        .unwrap();
    let mut a = active.remove(young);
    a.draft_kv.release(pool);
    sh.metrics.record_preemption();
    if let Some(w) = &sh.tracer {
        w.record(
            a.req.id,
            TraceEvent::Preempt {
                spilled: arena.enabled,
            },
        );
    }
    if arena.enabled {
        let kv_len = a.kv.len;
        let exports = a.kv.spill(pool);
        sh.metrics.record_kv_spill();
        if let Some(w) = &sh.tracer {
            w.record(
                a.req.id,
                TraceEvent::Spill {
                    pages: exports.len(),
                },
            );
        }
        arena.seqs.push(SpilledSeq {
            req: a.req,
            tx: a.tx,
            generated: a.generated,
            pending_prompt: a.pending_prompt,
            last_logits: a.last_logits,
            spec_k: a.spec_k,
            exports,
            kv_len,
            t0: a.t0,
            admitted_at: a.admitted_at,
            first_token_at: a.first_token_at,
        });
        return Freed::Spilled(young);
    }
    a.kv.release(pool);
    if let Some(w) = &sh.tracer {
        // Restart semantics: the request re-enters its class queue and
        // its discarded stream is re-derived deterministically.
        w.record(
            a.req.id,
            TraceEvent::Queued {
                class: a.req.priority,
            },
        );
    }
    sh.queue
        .lock()
        .unwrap()
        .push_front_classed((a.req, a.tx, a.t0));
    Freed::Removed(young)
}

struct Active {
    req: EngineRequest,
    tx: Sender<EngineResponse>,
    kv: PagedKv,
    generated: Vec<u8>,
    /// Pending prompt tokens not yet prefilled.
    pending_prompt: usize,
    last_logits: Vec<f32>,
    /// Resolved draft length for this request (request override or the
    /// engine default; 0 = plain decode).
    spec_k: usize,
    /// Draft-model KV, pages drawn from the same pool (empty until the
    /// first speculative round; only populated when `spec_k > 0`).
    draft_kv: PagedKv,
    /// True-stream tokens the draft model has not consumed yet. Seeded
    /// with the whole prompt at admission (the draft prefills itself in
    /// one chunk at the first speculative round — so prefix-forked
    /// prompts need no special casing) and thereafter holds at most the
    /// final accepted draft of an all-accept round.
    draft_pending: Vec<u8>,
    /// Submission time — carried through preemption/requeue so reported
    /// latency covers the request's whole life, queue wait included.
    t0: Instant,
    /// Admission order: preemption evicts the youngest admission first,
    /// so the oldest sequence always makes progress.
    admit_seq: u64,
    /// When the admission that produced the surviving token stream
    /// happened (`queue_ms = admitted_at − t0`). Spill/restore preserves
    /// it; a restart-preemption's re-admission resets it — the discarded
    /// stream's admission no longer matters.
    admitted_at: Instant,
    /// When the first surviving token was emitted (`ttft_ms`); reset
    /// together with `admitted_at` on restart semantics.
    first_token_at: Option<Instant>,
}

/// One queued submission: the request, its answer channel, and its
/// submit time (latency covers queue wait).
type Queued = (EngineRequest, Sender<EngineResponse>, Instant);

/// The submit queue, priority-ordered: descending
/// [`EngineRequest::priority`], FIFO within a class. `killed` flips
/// (under the same lock, so no submission can race past it) when the
/// engine is torn down by [`NativeEngine::kill`] — subsequent submits
/// are refused by dropping their answer channel, which a fleet router
/// observes as a disconnect and re-routes.
struct SubmitQueue {
    q: VecDeque<Queued>,
    killed: bool,
}

impl SubmitQueue {
    fn new() -> Self {
        SubmitQueue {
            q: VecDeque::new(),
            killed: false,
        }
    }

    /// Enqueue a fresh submission: behind every queued request of its
    /// class or higher — FIFO within a class, ahead of lower classes.
    fn push_back_classed(&mut self, item: Queued) {
        let pri = item.0.priority;
        let at = self
            .q
            .iter()
            .position(|(r, _, _)| r.priority < pri)
            .unwrap_or(self.q.len());
        self.q.insert(at, item);
    }

    /// Re-enqueue a preempted request: at the *front* of its class
    /// (ahead of equal-priority peers — it already held pages and must
    /// not starve behind an endless stream of its own class), still
    /// behind every strictly-higher class.
    fn push_front_classed(&mut self, item: Queued) {
        let pri = item.0.priority;
        let at = self
            .q
            .iter()
            .position(|(r, _, _)| r.priority <= pri)
            .unwrap_or(self.q.len());
        self.q.insert(at, item);
    }
}

struct Shared {
    queue: Mutex<SubmitQueue>,
    stop: AtomicBool,
    /// Hard-kill switch ([`NativeEngine::kill`]): unlike `stop` (drain
    /// and exit), the scheduler abandons active sequences immediately —
    /// dropping their answer channels — to simulate/handle a dead
    /// replica. The fleet router's watchers observe the disconnects and
    /// re-route.
    die: AtomicBool,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
    /// Model context length, for submit-time validation.
    ctx: usize,
    /// Registered reusable prompt prefixes (the scheduler caches their
    /// KV lazily, keyed by id, and rebuilds on re-registration).
    prefixes: Mutex<Vec<PrefixDef>>,
    /// Lifecycle-trace writer bound to this engine's replica shard
    /// ([`crate::serve::trace`]); `None` disables event recording *and*
    /// the scheduler thread's phase-timer sink.
    tracer: Option<TraceWriter>,
}

/// Native-backend engine: owns the model (optionally quantized), the
/// shared KV page pool, and a scheduler thread.
pub struct NativeEngine {
    shared: Arc<Shared>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

/// Tunables for [`NativeEngine::start_with_opts`]. `Default` matches
/// [`NativeEngine::start`]'s behavior: worst-case pool, fused
/// attention, speculation off unless a request asks for it.
#[derive(Clone, Debug)]
pub struct EngineOptions {
    /// Maximum concurrently active sequences.
    pub max_batch: usize,
    /// KV pool size in pages; `None` = worst case
    /// (`max_batch × pages_per_seq`, never preempts).
    pub pool_pages: Option<usize>,
    /// Attention kernel for the scheduler's generators (fused
    /// cross-sequence walk by default; [`AttnMode::PerSeq`] keeps the
    /// per-sequence baseline for A/B debugging — logits are bit-exact
    /// either way).
    pub attn_mode: AttnMode,
    /// Default draft length for requests that leave
    /// [`EngineRequest::speculate_k`] unset (0 = off).
    pub speculate_k: usize,
    /// KV-cache quantization rate for cold pages: 0 (default) keeps the
    /// whole pool fp32 and bit-exact with the pre-quantization engine;
    /// 2 or 4 enable the E8P/RVQ cold tier
    /// ([`crate::generation::paged::KvQuantSpec`]) and the spill arena
    /// for preempted sequences.
    pub kv_bits: usize,
    /// Recent full pages per sequence kept fp32 behind the write head
    /// when `kv_bits > 0` (the hot tail; the partially written page is
    /// always fp32 on top of this).
    pub kv_hot_pages: usize,
    /// Request-lifecycle trace writer ([`crate::serve::trace`]). `None`
    /// (default) turns tracing — and the scheduler's phase profiling —
    /// off entirely; the engine then pays only an `Option` check per
    /// would-be event. [`NativeEngine::start_replicas`] rebinds the
    /// writer to each replica's shard.
    pub tracer: Option<TraceWriter>,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            max_batch: 8,
            pool_pages: None,
            attn_mode: AttnMode::Fused,
            speculate_k: 0,
            kv_bits: 0,
            kv_hot_pages: 1,
            tracer: None,
        }
    }
}

impl NativeEngine {
    /// `qm` enables the fused E8P decode path per layer. The KV pool is
    /// sized for the worst case (`max_batch` full-context sequences), so
    /// this constructor never preempts; see
    /// [`NativeEngine::start_with_pool`] to oversubscribe and
    /// [`NativeEngine::start_with_opts`] for the full knob set.
    pub fn start(model: Arc<Model>, qm: Option<Arc<QuantizedModel>>, max_batch: usize) -> Self {
        Self::start_with_opts(
            model,
            qm,
            EngineOptions {
                max_batch,
                ..EngineOptions::default()
            },
        )
    }

    /// Start with an explicit KV pool size (in pages of
    /// [`crate::generation::paged::PAGE_ROWS`] token rows; one page holds
    /// every layer's K and V for those rows). Sizing the pool below
    /// `max_batch × paged::pages_per_seq(&cfg)` oversubscribes KV: admission
    /// continues while pages remain, and when an allocation fails the
    /// youngest active sequence is preempted — its pages return to the
    /// pool and its request is requeued (restarted later; greedy decode
    /// makes the retry deterministic).
    pub fn start_with_pool(
        model: Arc<Model>,
        qm: Option<Arc<QuantizedModel>>,
        max_batch: usize,
        pool_pages: usize,
    ) -> Self {
        Self::start_with_opts(
            model,
            qm,
            EngineOptions {
                max_batch,
                pool_pages: Some(pool_pages),
                ..EngineOptions::default()
            },
        )
    }

    /// Start with the full option set ([`EngineOptions`]): pool sizing,
    /// attention-kernel selection, and the default self-speculative
    /// draft length. When `qm` is present the scheduler also builds the
    /// RVQ base-stage draft generator
    /// ([`crate::qmodel::QuantizedModel::draft_generator`]), whose KV
    /// pages come from the same pool as the targets'; a dense engine
    /// self-drafts (useful for exercising the path, not for speed).
    pub fn start_with_opts(
        model: Arc<Model>,
        qm: Option<Arc<QuantizedModel>>,
        opts: EngineOptions,
    ) -> Self {
        let max_batch = opts.max_batch;
        let pool_pages = opts
            .pool_pages
            .unwrap_or_else(|| max_batch.max(1) * pages_per_seq(&model.cfg));
        let shared = Arc::new(Shared {
            queue: Mutex::new(SubmitQueue::new()),
            stop: AtomicBool::new(false),
            die: AtomicBool::new(false),
            metrics: Arc::new(Metrics::new()),
            next_id: AtomicU64::new(1),
            ctx: model.cfg.ctx,
            prefixes: Mutex::new(Vec::new()),
            tracer: opts.tracer.clone(),
        });
        let sh = shared.clone();
        let handle = std::thread::spawn(move || {
            // Phase attribution is part of the tracing opt-in: without a
            // tracer the instrumented kernels skip even the clock read.
            if sh.tracer.is_some() {
                crate::util::phase::install(sh.metrics.phases());
            }
            let mut generator = match &qm {
                Some(q) => Generator::quantized(&model, q),
                None => Generator::dense(&model),
            };
            generator.attn_mode = opts.attn_mode;
            // Draft model for self-speculative rounds: the RVQ base
            // stage when quantized (codes Arc-shared with the target; a
            // single-stage model degenerates to self-drafting), the
            // model itself when dense.
            let mut draft_gen = match &qm {
                Some(q) => Generator::base_stage(&model, q),
                None => Generator::dense(&model),
            };
            draft_gen.attn_mode = opts.attn_mode;
            let wb_split = generator.weight_bytes_split();
            let draft_split = draft_gen.weight_bytes_split();
            let weight_bytes = wb_split.0 + wb_split.1 + wb_split.2;
            let kv_quant = (opts.kv_bits > 0).then(|| KvQuantSpec {
                bits: opts.kv_bits,
                hot_pages: opts.kv_hot_pages,
            });
            let mut pool = KvPagePool::for_model_quant(&model, pool_pages.max(1), kv_quant);
            sh.metrics.set_pool_capacity(pool.pages_total());
            let mut active: Vec<Active> = Vec::new();
            let mut prefix_cache: HashMap<u64, PrefixCache> = HashMap::new();
            let mut arena = SpillArena::new(kv_quant.is_some());
            let mut admit_counter: u64 = 0;
            let ctx = model.cfg.ctx;
            loop {
                if sh.die.load(Ordering::Relaxed) {
                    break;
                }
                if sh.stop.load(Ordering::Relaxed) && active.is_empty() {
                    break;
                }
                // Admit (FIFO): pool-aware — a request joins while free
                // pages outnumber this round's admissions (each admission
                // will claim its first page at the first decode round),
                // rather than reserving worst-case `ctx` pages up front.
                // Counting admissions against the free pages avoids
                // admit-then-evict churn when only one page is left. The
                // queue lock is taken per pop, so a slow admission (a
                // one-time prefix-cache prefill) never blocks submitters.
                let mut newly = 0usize;
                while active.len() < max_batch && (active.is_empty() || pool.pages_free() > newly) {
                    // Spilled sequences re-admit first (FIFO): their KV
                    // restores by import, so they resume mid-stream with
                    // no re-prefill. A capacity miss holds all further
                    // admissions (nothing younger may jump the arena)
                    // until retirements free units — unless the pool is
                    // as empty as it can get, in which case the sequence
                    // can never fit and fails descriptively.
                    if !arena.seqs.is_empty() {
                        let mut s = arena.seqs.remove(0);
                        let mut kv = PagedKv::new();
                        let restore_pages = s.exports.len();
                        if kv.restore(&mut pool, &mut s.exports, s.kv_len) {
                            newly += 1;
                            admit_counter += 1;
                            sh.metrics.record_kv_restore();
                            if let Some(w) = &sh.tracer {
                                // `restore` is the re-admission: the
                                // stream picks up exactly where it
                                // stopped, so no fresh `admit` follows.
                                w.record(
                                    s.req.id,
                                    TraceEvent::Restore {
                                        pages: restore_pages,
                                    },
                                );
                            }
                            // The draft KV was released at spill; it
                            // re-consumes the whole true stream (prompt +
                            // generated) at its next speculative round,
                            // exactly like a fresh admission whose prompt
                            // were that long.
                            let draft_pending = if s.spec_k > 0 {
                                let mut p = s.req.prompt.clone();
                                p.extend_from_slice(&s.generated);
                                p
                            } else {
                                Vec::new()
                            };
                            active.push(Active {
                                req: s.req,
                                tx: s.tx,
                                kv,
                                generated: s.generated,
                                pending_prompt: s.pending_prompt,
                                last_logits: s.last_logits,
                                spec_k: s.spec_k,
                                draft_kv: PagedKv::new(),
                                draft_pending,
                                t0: s.t0,
                                admit_seq: admit_counter,
                                admitted_at: s.admitted_at,
                                first_token_at: s.first_token_at,
                            });
                            continue;
                        }
                        if active.is_empty() {
                            // With no live sequences every cache is cold;
                            // unpin one and retry. Once nothing is left
                            // to unpin the pool is as free as it gets.
                            if evict_cold_prefix(
                                &mut prefix_cache,
                                &mut pool,
                                &mut arena,
                                &sh.metrics,
                                None,
                            ) {
                                arena.seqs.insert(0, s);
                                continue;
                            }
                            sh.metrics.record_failed();
                            let msg = format!(
                                "KV pool too small to restore spilled sequence: \
                                 {} pages of exported KV against a pool of {}",
                                s.exports.len(),
                                pool.pages_total()
                            );
                            if let Some(w) = &sh.tracer {
                                w.finish(s.req.id, TraceEvent::Fail { reason: msg.clone() });
                            }
                            let resp = EngineResponse {
                                id: s.req.id,
                                tokens: s.generated,
                                latency_ms: s.t0.elapsed().as_secs_f64() * 1e3,
                                prompt_len: s.req.prompt.len(),
                                error: Some(msg),
                            };
                            let _ = s.tx.send(resp);
                            continue;
                        }
                        arena.seqs.insert(0, s);
                        break;
                    }
                    let popped = sh.queue.lock().unwrap().q.pop_front();
                    let Some((req, tx, t0)) = popped else { break };
                    newly += 1;
                    admit_counter += 1;
                    let admitted_at = Instant::now();
                    if let Some(w) = &sh.tracer {
                        w.record(
                            req.id,
                            TraceEvent::Admit {
                                replica: w.replica(),
                            },
                        );
                    }
                    let mut kv = PagedKv::new();
                    let mut pending_prompt = req.prompt.len();
                    let mut last_logits = Vec::new();
                    // Prefix sharing: fork a registered prompt prefix
                    // (sharing its KV pages, skipping its prefill) and
                    // only decode the unshared remainder of the prompt.
                    let fork = try_fork_prefix(
                        &req,
                        &sh,
                        &generator,
                        &mut pool,
                        &mut prefix_cache,
                        &mut arena,
                        &mut kv,
                        admit_counter,
                    );
                    if let Some((fork_rows, logits)) = fork {
                        pending_prompt = req.prompt.len() - fork_rows;
                        if let Some(l) = logits {
                            last_logits = l;
                        }
                        // Count only fully occupied pages as saved: the
                        // partial tail page is also shared at fork, but
                        // the first write clones it back (copy-on-write),
                        // so it is not a lasting saving.
                        sh.metrics.record_prefix_hit(fork_rows / PAGE_ROWS);
                    }
                    let spec_k = req.speculate_k.unwrap_or(opts.speculate_k);
                    // The draft model consumes the whole prompt itself
                    // (one chunked step at the first speculative round),
                    // so forked prompts need no draft-side special case.
                    let draft_pending = if spec_k > 0 { req.prompt.clone() } else { Vec::new() };
                    active.push(Active {
                        req,
                        tx,
                        kv,
                        generated: Vec::new(),
                        pending_prompt,
                        last_logits,
                        spec_k,
                        draft_kv: PagedKv::new(),
                        draft_pending,
                        t0,
                        admit_seq: admit_counter,
                        admitted_at,
                        first_token_at: None,
                    });
                }
                if active.is_empty() {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    continue;
                }
                // One scheduler step = up to PREFILL_CHUNK batched decode
                // rounds. Round 0 advances every sequence by one token
                // (next prompt token while prefilling, next-token
                // continuation — argmax or the position-keyed sample —
                // otherwise); later rounds only run sequences still in
                // prefill, so long prompts are consumed in batched slices
                // without re-decoding weights per sequence.
                for round in 0..PREFILL_CHUNK {
                    // Select (active index, token, is_prefill) triples,
                    // in admission order.
                    let mut sel: Vec<(usize, u8, bool)> = Vec::new();
                    for (i, a) in active.iter_mut().enumerate() {
                        if a.pending_prompt > 0 {
                            let idx = a.req.prompt.len() - a.pending_prompt;
                            a.pending_prompt -= 1;
                            sel.push((i, a.req.prompt[idx], true));
                        } else if round == 0 && a.spec_k == 0 && a.generated.len() < a.req.max_new
                        {
                            // The budget check matters for whole-prompt
                            // prefix hits, which arrive with pending 0
                            // and ready logits: a max_new = 0 request
                            // must retire with 0 tokens, exactly like
                            // the unshared path (where the retire sweep
                            // runs before any round-0 continuation).
                            // Speculating sequences (spec_k > 0) sit out
                            // the round-0 continuation: they advance in
                            // the speculative phase below instead.
                            let pos = a.req.prompt.len() + a.generated.len();
                            let t = next_token(&a.last_logits, &a.req.sampling, pos);
                            a.generated.push(t);
                            sel.push((i, t, false));
                        }
                    }
                    if sel.is_empty() {
                        break;
                    }
                    // Reserve this round's KV pages, relieving pressure
                    // via [`free_pages`] (retire finished → unpin cold
                    // prefix caches → preempt the youngest) until every
                    // selected sequence has its page or nothing is left
                    // to free.
                    loop {
                        let mut exhausted = false;
                        for &(i, _, _) in &sel {
                            let need = active[i].kv.len + 1;
                            if !active[i].kv.reserve(&mut pool, need) {
                                exhausted = true;
                                break;
                            }
                        }
                        if !exhausted {
                            break;
                        }
                        let freed = free_pages(
                            &mut active,
                            &mut pool,
                            &sh,
                            &mut prefix_cache,
                            &mut arena,
                            ctx,
                        );
                        match freed {
                            Freed::PrefixEvicted => continue,
                            Freed::Removed(victim) | Freed::Spilled(victim) => {
                                // A spilled victim resumes exactly where
                                // its last completed decode stopped, but
                                // the selection pass above already
                                // advanced its cursor (prompt token
                                // consumed, or continuation token pushed)
                                // for a decode that now never runs. Undo
                                // that advance on the parked copy; greedy
                                // determinism re-derives the same token
                                // from the same logits after restore.
                                if matches!(freed, Freed::Spilled(_)) {
                                    if let Some(&(_, _, was_prefill)) =
                                        sel.iter().find(|&&(j, _, _)| j == victim)
                                    {
                                        let s = arena.seqs.last_mut().unwrap();
                                        if was_prefill {
                                            s.pending_prompt += 1;
                                        } else {
                                            s.generated.pop();
                                        }
                                    }
                                }
                                sel.retain(|&(j, _, _)| j != victim);
                                for e in sel.iter_mut() {
                                    if e.0 > victim {
                                        e.0 -= 1;
                                    }
                                }
                                if sel.is_empty() {
                                    break;
                                }
                            }
                        }
                    }
                    if sel.is_empty() {
                        break;
                    }
                    // Count prefill tokens only for sequences that made
                    // it past reservation — evicted sequences' prompt
                    // tokens were never decoded this round (and will be
                    // recounted honestly when the request restarts).
                    let prefill_count = sel.iter().filter(|&&(_, _, p)| p).count();
                    let toks: Vec<u8> = sel.iter().map(|&(_, t, _)| t).collect();
                    let logits = {
                        // Collect the selected sequences' page tables via
                        // one ordered walk (sel indices are increasing).
                        let mut seqs: Vec<&mut PagedKv> = Vec::with_capacity(sel.len());
                        let mut si = 0usize;
                        for (i, a) in active.iter_mut().enumerate() {
                            if si < sel.len() && sel[si].0 == i {
                                seqs.push(&mut a.kv);
                                si += 1;
                            }
                        }
                        generator.decode_batch_paged(&toks, &mut pool, &mut seqs)
                    };
                    let batch = toks.len();
                    {
                        let mut logit_it = logits.into_iter();
                        let mut si = 0usize;
                        for (i, a) in active.iter_mut().enumerate() {
                            if si < sel.len() && sel[si].0 == i {
                                a.last_logits = logit_it.next().unwrap();
                                let was_prefill = sel[si].2;
                                si += 1;
                                // The continuation token pushed at
                                // selection survived the decode: stamp
                                // the first one for the ttft split
                                // (evicted entries left `sel` above, so
                                // an undone push can never stamp).
                                if !was_prefill && a.first_token_at.is_none() {
                                    a.first_token_at = Some(Instant::now());
                                }
                                if let Some(w) = &sh.tracer {
                                    w.record(
                                        a.req.id,
                                        if was_prefill {
                                            TraceEvent::Prefill { tokens: 1 }
                                        } else {
                                            TraceEvent::DecodeRound {
                                                tokens: 1,
                                                total: a.generated.len(),
                                                spec: false,
                                            }
                                        },
                                    );
                                }
                            }
                        }
                    }
                    sh.metrics.record_step(batch);
                    sh.metrics.record_prefill(prefill_count);
                    // Decode-once/multiply-many accounting: the batched
                    // kernel amortizes packed codes and dense linear
                    // weights across the round (per-lane lm_head traffic
                    // and per-BATCH_TILE code re-reads included), where a
                    // sequence-at-a-time loop streams everything per lane.
                    sh.metrics.record_decode_bytes(
                        streamed_bytes_for_batch(wb_split, batch),
                        weight_bytes * batch as u64,
                    );
                    sh.metrics.set_pages_in_use(pool.pages_in_use());
                    sh.metrics.set_shared_pages(pool.shared_pages());
                }
                // Speculative phase: sequences with spec_k > 0 that have
                // finished prefilling advance through one draft/verify
                // round per scheduler step — the base-stage draft
                // proposes up to k tokens against its own KV (pages from
                // the same pool), the target scores all k + 1 positions
                // in one chunked batched step, and both KVs roll back to
                // the last accepted token. The greedy accept rule keeps
                // responses bit-identical to plain decode; only
                // throughput changes.
                let mut spec_sel: Vec<usize> = active
                    .iter()
                    .enumerate()
                    .filter(|(_, a)| {
                        a.spec_k > 0
                            && a.pending_prompt == 0
                            && a.generated.len() < a.req.max_new
                            && a.kv.len < ctx
                    })
                    .map(|(i, _)| i)
                    .collect();
                if !spec_sel.is_empty() {
                    // Pre-reserve the round's worst case (target: k + 1
                    // rows; draft: pending + k rows), relieving pool
                    // pressure exactly like the decode rounds. The
                    // per-lane draft cap is deterministic in the lane's
                    // own state, so recomputing it after evictions is
                    // stable.
                    let lane_k = |a: &Active| {
                        effective_k(
                            a.spec_k,
                            a.req.max_new - a.generated.len(),
                            ctx,
                            a.kv.len,
                            a.draft_kv.len,
                            a.draft_pending.len(),
                        )
                    };
                    loop {
                        let mut exhausted = false;
                        for &i in &spec_sel {
                            let k = lane_k(&active[i]);
                            let t_need = active[i].kv.len + 1 + k;
                            // The draft phase only runs (and only then
                            // consumes pending + k rows) when k > 0; a
                            // lane capped to k = 0 must not pin draft
                            // pages it will never write.
                            let d_need = if k == 0 {
                                0
                            } else {
                                active[i].draft_kv.len + active[i].draft_pending.len() + k
                            };
                            let a = &mut active[i];
                            if !a.kv.reserve(&mut pool, t_need)
                                || !a.draft_kv.reserve(&mut pool, d_need)
                            {
                                exhausted = true;
                                break;
                            }
                        }
                        if !exhausted {
                            break;
                        }
                        match free_pages(
                            &mut active,
                            &mut pool,
                            &sh,
                            &mut prefix_cache,
                            &mut arena,
                            ctx,
                        ) {
                            Freed::PrefixEvicted => continue,
                            // Spec selection mutates no lane state before
                            // reservation, so a spilled victim needs no
                            // cursor repair here.
                            Freed::Removed(victim) | Freed::Spilled(victim) => {
                                spec_sel.retain(|&j| j != victim);
                                for j in spec_sel.iter_mut() {
                                    if *j > victim {
                                        *j -= 1;
                                    }
                                }
                                if spec_sel.is_empty() {
                                    break;
                                }
                            }
                        }
                    }
                    if !spec_sel.is_empty() {
                        let ks: Vec<usize> =
                            spec_sel.iter().map(|&i| lane_k(&active[i])).collect();
                        // Lane counts for byte accounting, captured
                        // before the round mutates pending.
                        let draft_chunk_lanes: usize = spec_sel
                            .iter()
                            .zip(&ks)
                            .filter(|&(_, &k)| k > 0)
                            .map(|(&i, _)| active[i].draft_pending.len() + 1)
                            .sum();
                        let verify_lanes: usize = ks.iter().map(|k| k + 1).sum();
                        let max_k = ks.iter().copied().max().unwrap_or(0);
                        let mut round_stats = SpecStats::default();
                        let emitted = {
                            let mut lanes: Vec<SpecLane> = Vec::with_capacity(spec_sel.len());
                            let mut si = 0usize;
                            for (i, a) in active.iter_mut().enumerate() {
                                if si < spec_sel.len() && spec_sel[si] == i {
                                    lanes.push(SpecLane {
                                        k: ks[si],
                                        target_kv: &mut a.kv,
                                        draft_kv: &mut a.draft_kv,
                                        pending: &mut a.draft_pending,
                                        logits: &mut a.last_logits,
                                        sampling: a.req.sampling,
                                        pos: a.req.prompt.len() + a.generated.len(),
                                    });
                                    si += 1;
                                }
                            }
                            spec_round_paged(
                                &generator,
                                &draft_gen,
                                &mut pool,
                                &mut lanes,
                                &mut round_stats,
                            )
                        };
                        let mut emitted_total = 0usize;
                        for (em, &i) in emitted.iter().zip(&spec_sel) {
                            active[i].generated.extend_from_slice(em);
                            emitted_total += em.len();
                            if !em.is_empty() && active[i].first_token_at.is_none() {
                                active[i].first_token_at = Some(Instant::now());
                            }
                            if let Some(w) = &sh.tracer {
                                w.record(
                                    active[i].req.id,
                                    TraceEvent::DecodeRound {
                                        tokens: em.len(),
                                        total: active[i].generated.len(),
                                        spec: true,
                                    },
                                );
                            }
                        }
                        sh.metrics.record_spec(
                            round_stats.tokens_drafted,
                            round_stats.tokens_accepted,
                            round_stats.rounds,
                            round_stats.tokens_resampled,
                        );
                        sh.metrics.record_step(spec_sel.len());
                        // Byte accounting: what the draft steps (base
                        // stage, batched across lanes) plus the single
                        // chunked verify step actually streamed, against
                        // what sequence-at-a-time target-only decode
                        // would stream for the tokens emitted.
                        let mut streamed = streamed_bytes_for_batch(wb_split, verify_lanes);
                        if max_k > 0 {
                            streamed += streamed_bytes_for_batch(draft_split, draft_chunk_lanes);
                            for j in 1..max_k {
                                let cnt = ks.iter().filter(|&&k| k > j).count();
                                if cnt == 0 {
                                    break;
                                }
                                streamed += streamed_bytes_for_batch(draft_split, cnt);
                            }
                        }
                        sh.metrics
                            .record_decode_bytes(streamed, weight_bytes * emitted_total as u64);
                        sh.metrics.set_pages_in_use(pool.pages_in_use());
                        sh.metrics.set_shared_pages(pool.shared_pages());
                    }
                }
                // Retire: release pages back to the pool and answer.
                active.retain_mut(|a| {
                    let done = a.pending_prompt == 0
                        && (a.generated.len() >= a.req.max_new || a.kv.len >= ctx);
                    if done {
                        a.kv.release(&mut pool);
                        a.draft_kv.release(&mut pool);
                        let resp = EngineResponse {
                            id: a.req.id,
                            tokens: std::mem::take(&mut a.generated),
                            latency_ms: a.t0.elapsed().as_secs_f64() * 1e3,
                            prompt_len: a.req.prompt.len(),
                            error: None,
                        };
                        retire_metrics(&sh, a, resp.tokens.len(), resp.latency_ms);
                        let _ = a.tx.send(resp);
                        false
                    } else {
                        true
                    }
                });
                sh.metrics.set_pages_in_use(pool.pages_in_use());
                sh.metrics.set_shared_pages(pool.shared_pages());
                sh.metrics.set_kv_quant_state(
                    pool.pages_quantized_total(),
                    pool.cold_pages(),
                    arena.pages(),
                );
                sh.metrics.set_codewords_decoded(codewords_decoded());
            }
            if sh.die.load(Ordering::Relaxed) {
                // Hard kill: abandon everything in flight. Dropping the
                // `Active`s, the spill arena, and the queued entries
                // drops their answer `Sender`s, so every waiting caller
                // sees a channel disconnect — the signal a fleet router
                // re-routes on. Mark the queue killed under its lock so
                // a submit racing this drain is refused rather than
                // parked forever.
                drop(active);
                arena.seqs.clear();
                let mut q = sh.queue.lock().unwrap();
                q.killed = true;
                q.q.clear();
            }
        });
        NativeEngine {
            shared,
            handle: Mutex::new(Some(handle)),
        }
    }

    pub fn fresh_id(&self) -> u64 {
        self.shared.next_id.fetch_add(1, Ordering::Relaxed)
    }

    pub fn join(&self) {
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }

    /// Hard-kill this engine: the scheduler abandons every in-flight and
    /// queued request at its next loop turn, dropping their answer
    /// channels, and later submits are refused the same way (immediate
    /// disconnect). This is the replica-death model the fleet router
    /// ([`crate::serve::router`]) recovers from — its watchers see the
    /// disconnects and re-route — and what the fault-injection e2e test
    /// uses to kill a replica mid-stream. Contrast [`Engine::stop`],
    /// which drains active work before exiting.
    pub fn kill(&self) {
        self.shared.die.store(true, Ordering::Relaxed);
    }

    /// Spin up `n` replicas of one model, each with its own KV page
    /// pool, scheduler thread, and metrics, all sharing `model` and
    /// `qm` by `Arc` — the packed codes and codebook tables are never
    /// duplicated, so a replica's marginal footprint is its KV pool
    /// plus scheduler state. This is the construction path for the
    /// fleet router ([`crate::serve::router::Router`]).
    pub fn start_replicas(
        model: Arc<Model>,
        qm: Option<Arc<QuantizedModel>>,
        n: usize,
        opts: EngineOptions,
    ) -> Vec<NativeEngine> {
        (0..n.max(1))
            .map(|i| {
                let mut o = opts.clone();
                // Each replica records into its own trace shard
                // (preserving submit ownership, so a router-less
                // single-replica fleet still opens its traces).
                o.tracer = opts.tracer.as_ref().map(|w| w.with_replica(i));
                Self::start_with_opts(model.clone(), qm.clone(), o)
            })
            .collect()
    }
}

impl Engine for NativeEngine {
    fn submit(&self, req: EngineRequest) -> Receiver<EngineResponse> {
        let (tx, rx) = channel();
        // Validate at submit time: a prompt that fills (or overflows) the
        // context can never produce a token, and used to fail only as an
        // assert deep in the generator.
        if req.prompt.len() >= self.shared.ctx {
            self.shared.metrics.record_rejected();
            let msg = format!(
                "prompt length {} exceeds model context {} (no room to generate)",
                req.prompt.len(),
                self.shared.ctx
            );
            if let Some(w) = &self.shared.tracer {
                if w.owns_submit() {
                    w.record(req.id, TraceEvent::Submit { class: req.priority });
                }
                w.finish(req.id, TraceEvent::Fail { reason: msg.clone() });
            }
            let _ = tx.send(EngineResponse {
                id: req.id,
                tokens: Vec::new(),
                latency_ms: 0.0,
                prompt_len: req.prompt.len(),
                error: Some(msg),
            });
            return rx;
        }
        let mut q = self.shared.queue.lock().unwrap();
        if q.killed || self.shared.die.load(Ordering::Relaxed) {
            // A killed engine answers nothing: dropping `tx` here
            // disconnects the receiver immediately, so the caller (or
            // the fleet router) learns at once instead of waiting on a
            // scheduler that will never run. Nothing is traced either —
            // a router retries elsewhere and the surviving attempt's
            // events tell the story.
            return rx;
        }
        if let Some(w) = &self.shared.tracer {
            if w.owns_submit() {
                w.record(req.id, TraceEvent::Submit { class: req.priority });
            }
            w.record(req.id, TraceEvent::Queued { class: req.priority });
        }
        q.push_back_classed((req, tx, Instant::now()));
        rx
    }

    fn metrics(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }

    fn stop(&self) {
        self.shared.stop.store(true, Ordering::Relaxed);
    }

    fn register_prefix(&self, id: u64, tokens: Vec<u8>) -> bool {
        // A usable prefix must leave room to generate: prompts of length
        // ≥ ctx are rejected at submit time anyway.
        if tokens.is_empty() || tokens.len() >= self.shared.ctx {
            return false;
        }
        let mut defs = self.shared.prefixes.lock().unwrap();
        let tokens = Arc::new(tokens);
        match defs.iter_mut().find(|d| d.id == id) {
            Some(d) => d.tokens = tokens,
            None => defs.push(PrefixDef { id, tokens }),
        }
        true
    }

    fn trace_json(&self, id: u64) -> crate::util::json::Json {
        match &self.shared.tracer {
            Some(w) => w.tracer().trace_json(id),
            None => crate::util::json::Json::obj(vec![(
                "error",
                crate::util::json::Json::str("tracing is not enabled on this backend"),
            )]),
        }
    }
}

impl Drop for NativeEngine {
    fn drop(&mut self) {
        self.stop();
        self.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests_support::tiny_model;
    use crate::model::{Arch, ModelConfig};

    #[test]
    fn engine_serves_requests() {
        let model = Arc::new(tiny_model(1));
        let eng = NativeEngine::start(model.clone(), None, 4);
        let mut rxs = Vec::new();
        for i in 0..6 {
            let rx = eng.submit(EngineRequest {
                id: i,
                prompt: vec![1, 2, 3, (i % 60) as u8],
                max_new: 5,
                prefix_id: None,
                speculate_k: None,
                priority: 0,
                sampling: Default::default(),
            });
            rxs.push(rx);
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
            assert_eq!(resp.id, i as u64);
            assert_eq!(resp.tokens.len(), 5);
            assert!(resp.error.is_none());
        }
        let m = eng.metrics();
        assert_eq!(m.requests_completed.load(Ordering::Relaxed), 6);
        // With max_batch 4 and 6 requests, some steps must have batched >1.
        assert!(m.mean_batch() > 1.0, "mean batch {}", m.mean_batch());
        // The batched kernel amortizes weight traffic across the batch.
        assert!(m.bytes_amortization() > 1.0, "amortization {}", m.bytes_amortization());
        eng.stop();
        eng.join();
        // Worst-case pool: everything fits, nothing is ever preempted,
        // and retirement returns every page (gauge read after join, when
        // the scheduler thread has quiesced).
        assert_eq!(m.preemptions.load(Ordering::Relaxed), 0);
        assert_eq!(m.pages_in_use.load(Ordering::Relaxed), 0);
        assert!(m.peak_pages_in_use.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn engine_matches_offline_generation() {
        let model = Arc::new(tiny_model(2));
        let eng = NativeEngine::start(model.clone(), None, 2);
        let prompt = vec![4u8, 8, 15];
        let rx = eng.submit(EngineRequest {
            id: 9,
            prompt: prompt.clone(),
            max_new: 6,
            prefix_id: None,
            speculate_k: None,
            priority: 0,
            sampling: Default::default(),
        });
        let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        let offline = Generator::dense(&model).generate(&prompt, 6);
        assert_eq!(resp.tokens, offline);
        eng.stop();
        eng.join();
    }

    #[test]
    fn chunked_prefill_matches_offline_generation() {
        // A prompt longer than PREFILL_CHUNK is consumed in batched
        // slices across scheduler steps; the generated continuation must
        // be identical to offline token-by-token generation.
        let model = Arc::new(tiny_model(3));
        let eng = NativeEngine::start(model.clone(), None, 3);
        let long_prompt: Vec<u8> = (0..(2 * PREFILL_CHUNK + 3))
            .map(|i| ((i * 11 + 5) % 60) as u8)
            .collect();
        let short_prompt = vec![7u8, 2];
        let rx_long = eng.submit(EngineRequest {
            id: 1,
            prompt: long_prompt.clone(),
            max_new: 6,
            prefix_id: None,
            speculate_k: None,
            priority: 0,
            sampling: Default::default(),
        });
        let rx_short = eng.submit(EngineRequest {
            id: 2,
            prompt: short_prompt.clone(),
            max_new: 6,
            prefix_id: None,
            speculate_k: None,
            priority: 0,
            sampling: Default::default(),
        });
        let gen = Generator::dense(&model);
        let resp_long = rx_long
            .recv_timeout(std::time::Duration::from_secs(30))
            .unwrap();
        let resp_short = rx_short
            .recv_timeout(std::time::Duration::from_secs(30))
            .unwrap();
        assert_eq!(resp_long.tokens, gen.generate(&long_prompt, 6));
        assert_eq!(resp_short.tokens, gen.generate(&short_prompt, 6));
        // Prefill accounting saw the long prompt.
        let m = eng.metrics();
        let prefill = m.prefill_tokens.load(Ordering::Relaxed) as usize;
        assert_eq!(prefill, long_prompt.len() + short_prompt.len());
        eng.stop();
        eng.join();
    }

    #[test]
    fn rejects_overlong_prompt_at_submit() {
        let model = Arc::new(tiny_model(4));
        let ctx = model.cfg.ctx;
        let eng = NativeEngine::start(model.clone(), None, 2);
        // Exactly ctx (no room to generate) and well past ctx: both are
        // answered immediately with a descriptive error, never enqueued.
        for plen in [ctx, ctx + 9] {
            let rx = eng.submit(EngineRequest {
                id: 77,
                prompt: vec![1u8; plen],
                max_new: 4,
                prefix_id: None,
                speculate_k: None,
                priority: 0,
                sampling: Default::default(),
            });
            let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
            assert!(resp.tokens.is_empty());
            assert_eq!(resp.prompt_len, plen);
            let err = resp.error.expect("expected a rejection error");
            assert!(err.contains("exceeds model context"), "{err}");
        }
        assert_eq!(eng.metrics().requests_rejected.load(Ordering::Relaxed), 2);
        // A fitting prompt still goes through on the same engine.
        let rx = eng.submit(EngineRequest {
            id: 78,
            prompt: vec![1, 2, 3],
            max_new: 2,
            prefix_id: None,
            speculate_k: None,
            priority: 0,
            sampling: Default::default(),
        });
        let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert!(resp.error.is_none());
        assert_eq!(resp.tokens.len(), 2);
        eng.stop();
        eng.join();
    }

    /// Tiny model with a configurable multi-page context (tiny_model's
    /// ctx is a single page, so it can't exercise paging pressure).
    fn multi_page_model(seed: u64, ctx: usize) -> Model {
        let cfg = ModelConfig {
            name: "tiny2p".into(),
            d_model: 32,
            n_layers: 1,
            n_heads: 2,
            d_ff: 64,
            vocab: 64,
            ctx,
            arch: Arch::Llama,
            n_experts: 2,
        };
        Model::random(cfg, seed)
    }

    /// ctx = 64 = two KV pages per worst-case sequence, so a small pool
    /// creates real paging pressure.
    fn two_page_model(seed: u64) -> Model {
        multi_page_model(seed, 64)
    }

    #[test]
    fn preemption_requeues_and_completes() {
        // Pool of 2 pages, but each finished sequence spans 2 pages and
        // up to two run concurrently: allocations must fail, the youngest
        // sequence must be preempted (pages released, request requeued),
        // and every request must still complete with the exact offline
        // greedy continuation.
        let model = Arc::new(two_page_model(5));
        assert_eq!(pages_per_seq(&model.cfg), 2);
        let eng = NativeEngine::start_with_pool(model.clone(), None, 2, 2);
        let gen = Generator::dense(&model);
        let max_new = 40; // 2 + 40 rows = 2 pages per sequence
        let mut rxs = Vec::new();
        let mut prompts = Vec::new();
        for i in 0..3u64 {
            let prompt = vec![(3 + 5 * i) as u8, (7 + i) as u8];
            rxs.push(eng.submit(EngineRequest {
                id: i,
                prompt: prompt.clone(),
                max_new,
                prefix_id: None,
                speculate_k: None,
                priority: 0,
                sampling: Default::default(),
            }));
            prompts.push(prompt);
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
            assert!(resp.error.is_none(), "request {i}: {:?}", resp.error);
            assert_eq!(
                resp.tokens,
                gen.generate(&prompts[i], max_new),
                "request {i} diverged after preemption/requeue"
            );
        }
        let m = eng.metrics();
        eng.stop();
        eng.join();
        assert!(
            m.preemptions.load(Ordering::Relaxed) > 0,
            "pool pressure never triggered a preemption"
        );
        assert_eq!(m.pages_in_use.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn paged_admission_beats_worst_case_reservation() {
        // Pool of 3 pages with 2-page worst-case sequences: contiguous
        // worst-case-ctx reservation could admit only one sequence, but
        // short requests touch a single page each, so the paged engine
        // runs several concurrently.
        let model = Arc::new(two_page_model(6));
        let pool_pages = 3;
        let worst_case_admissible = pool_pages / pages_per_seq(&model.cfg);
        assert_eq!(worst_case_admissible, 1);
        let eng = NativeEngine::start_with_pool(model.clone(), None, 4, pool_pages);
        let mut rxs = Vec::new();
        for i in 0..4u64 {
            rxs.push(eng.submit(EngineRequest {
                id: i,
                prompt: vec![2, (i + 1) as u8],
                max_new: 20, // 22 rows: one page per sequence
                prefix_id: None,
                speculate_k: None,
                priority: 0,
                sampling: Default::default(),
            }));
        }
        for rx in rxs {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
            assert!(resp.error.is_none());
            assert_eq!(resp.tokens.len(), 20);
        }
        let m = eng.metrics();
        let peak = m.peak_batch.load(Ordering::Relaxed) as usize;
        assert!(
            peak > worst_case_admissible,
            "paged admission reached {peak}, no better than worst-case {worst_case_admissible}"
        );
        eng.stop();
        eng.join();
    }

    #[test]
    fn prefix_sharing_forks_instead_of_prefilling() {
        let model = Arc::new(two_page_model(8));
        let eng = NativeEngine::start(model.clone(), None, 4);
        let gen = Generator::dense(&model);
        // A system prompt spanning one full KV page plus a partial tail.
        let prefix: Vec<u8> = (0..40).map(|i| ((i * 3 + 1) % 60) as u8).collect();
        assert!(eng.register_prefix(7, prefix.clone()));
        let mut rxs = Vec::new();
        let mut prompts = Vec::new();
        for i in 0..4u64 {
            let mut prompt = prefix.clone();
            prompt.push((i + 1) as u8);
            rxs.push(eng.submit(EngineRequest {
                id: i,
                prompt: prompt.clone(),
                max_new: 6,
                prefix_id: None, // auto-detect against the registry
                speculate_k: None,
                priority: 0,
                sampling: Default::default(),
            }));
            prompts.push(prompt);
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
            assert!(resp.error.is_none());
            assert_eq!(
                resp.tokens,
                gen.generate(&prompts[i], 6),
                "request {i} diverged under prefix sharing"
            );
        }
        let m = eng.metrics();
        eng.stop();
        eng.join();
        assert_eq!(m.prefix_hits.load(Ordering::Relaxed), 4);
        // Each fork lastingly shared the prefix's one full page (the
        // partial tail page is cloned back by the first write, so it is
        // not counted as saved).
        assert_eq!(m.pages_saved.load(Ordering::Relaxed), 4);
        // Forked prompts skip the shared rows: total prefill is the
        // prefix once (the cache build) plus one unshared token per
        // request.
        let prefill = m.prefill_tokens.load(Ordering::Relaxed) as usize;
        assert_eq!(prefill, prefix.len() + 4);
        // Retirement released every per-request page; only the pinned
        // prefix cache stays resident.
        assert_eq!(m.pages_in_use.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn explicit_prefix_id_and_whole_prompt_fork() {
        let model = Arc::new(two_page_model(9));
        let eng = NativeEngine::start(model.clone(), None, 2);
        let gen = Generator::dense(&model);
        let sys: Vec<u8> = (0..36).map(|i| ((i * 5 + 2) % 60) as u8).collect();
        assert!(eng.register_prefix(1, sys.clone()));
        // Unusable registrations are refused outright.
        assert!(!eng.register_prefix(2, Vec::new()));
        assert!(!eng.register_prefix(2, vec![1u8; model.cfg.ctx]));
        // Prompt exactly equal to the registered prefix: the fork covers
        // the whole prompt and generation starts from cached logits.
        let rx = eng.submit(EngineRequest {
            id: 5,
            prompt: sys.clone(),
            max_new: 5,
            prefix_id: Some(1),
            speculate_k: None,
            priority: 0,
            sampling: Default::default(),
        });
        let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert!(resp.error.is_none());
        assert_eq!(resp.tokens, gen.generate(&sys, 5));
        let m = eng.metrics();
        assert_eq!(m.prefix_hits.load(Ordering::Relaxed), 1);
        // No prefill beyond the one-time cache build.
        assert_eq!(m.prefill_tokens.load(Ordering::Relaxed), sys.len() as u64);
        // An unknown prefix_id is a miss, not an error.
        let rx = eng.submit(EngineRequest {
            id: 6,
            prompt: vec![1, 2, 3],
            max_new: 3,
            prefix_id: Some(99),
            speculate_k: None,
            priority: 0,
            sampling: Default::default(),
        });
        let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert!(resp.error.is_none());
        assert_eq!(resp.tokens, gen.generate(&[1, 2, 3], 3));
        assert_eq!(m.prefix_hits.load(Ordering::Relaxed), 1);
        // max_new = 0 on a whole-prompt hit retires with 0 tokens, same
        // as the unshared path (the cached logits must not leak a free
        // continuation token).
        let rx = eng.submit(EngineRequest {
            id: 7,
            prompt: sys.clone(),
            max_new: 0,
            prefix_id: Some(1),
            speculate_k: None,
            priority: 0,
            sampling: Default::default(),
        });
        let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert!(resp.error.is_none());
        assert!(resp.tokens.is_empty());
        eng.stop();
        eng.join();
    }

    #[test]
    fn forked_children_under_pool_pressure_complete_exactly() {
        // ctx = 96 (3 pages/seq worst case); the pool holds the 2-page
        // prefix cache plus 4 more pages, while 3 children each need up
        // to 2 own pages (CoW tail clone + one growth page). Whatever
        // preemptions the timing produces, every response must equal the
        // offline continuation and the shared cache must survive.
        let model = Arc::new(multi_page_model(10, 96));
        let eng = NativeEngine::start_with_pool(model.clone(), None, 3, 6);
        let gen = Generator::dense(&model);
        let prefix: Vec<u8> = (0..40).map(|i| ((i * 7 + 3) % 60) as u8).collect();
        assert!(eng.register_prefix(3, prefix.clone()));
        let mut rxs = Vec::new();
        let mut prompts = Vec::new();
        for i in 0..3u64 {
            let mut prompt = prefix.clone();
            prompt.push((40 + i) as u8);
            rxs.push(eng.submit(EngineRequest {
                id: i,
                prompt: prompt.clone(),
                max_new: 24, // 41 + 24 = 65 rows: crosses into a 3rd page
                prefix_id: Some(3),
                speculate_k: None,
                priority: 0,
                sampling: Default::default(),
            }));
            prompts.push(prompt);
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
            assert!(resp.error.is_none(), "request {i}: {:?}", resp.error);
            assert_eq!(resp.tokens, gen.generate(&prompts[i], 24), "request {i} diverged");
        }
        let m = eng.metrics();
        eng.stop();
        eng.join();
        // Every admission forked (re-admissions after any preemption
        // fork again, so hits ≥ the request count).
        assert!(m.prefix_hits.load(Ordering::Relaxed) >= 3);
        assert!(m.pages_saved.load(Ordering::Relaxed) >= 3);
        // Only the pinned prefix cache stays resident.
        assert_eq!(m.pages_in_use.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn speculative_requests_match_offline_generation() {
        use crate::qmodel::quantize_model;
        use crate::quant::pipeline::Method;
        use std::collections::BTreeMap;
        // 4-bit RVQ model: the engine's draft generator is the embedded
        // 2-bit base stage. Every speculated response must be
        // bit-identical to plain greedy decode.
        let model = two_page_model(11);
        let qm = quantize_model(
            &model,
            &BTreeMap::new(),
            &Method::QuipSharp { bits: 4, ft: false },
            1,
        )
        .unwrap();
        assert!(qm.has_multi_stage());
        let model_arc = qm.serving_model();
        let offline: Vec<Vec<u8>> = (0..4u64)
            .map(|i| qm.generator().generate(&[2, (i + 1) as u8, 7], 12))
            .collect();
        let eng = NativeEngine::start_with_opts(
            model_arc,
            Some(Arc::new(qm)),
            EngineOptions {
                max_batch: 4,
                // Room for target + draft KV per sequence.
                pool_pages: Some(16),
                ..EngineOptions::default()
            },
        );
        let mut rxs = Vec::new();
        for i in 0..4u64 {
            rxs.push(eng.submit(EngineRequest {
                id: i,
                prompt: vec![2, (i + 1) as u8, 7],
                max_new: 12,
                prefix_id: None,
                speculate_k: Some(4),
                priority: 0,
                sampling: Default::default(),
            }));
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
            assert!(resp.error.is_none(), "request {i}: {:?}", resp.error);
            assert_eq!(resp.tokens, offline[i], "request {i} diverged under speculation");
        }
        let m = eng.metrics();
        eng.stop();
        eng.join();
        assert!(m.tokens_drafted.load(Ordering::Relaxed) > 0, "nothing was drafted");
        assert!(m.spec_rounds.load(Ordering::Relaxed) > 0);
        // Draft and target pages all released at retirement.
        assert_eq!(m.pages_in_use.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn dense_self_draft_accepts_everything() {
        // Dense engine: the draft *is* the target, so every draft token
        // verifies — and the engine-wide default (EngineOptions)
        // applies when requests leave speculate_k unset.
        let model = Arc::new(two_page_model(12));
        let eng = NativeEngine::start_with_opts(
            model.clone(),
            None,
            EngineOptions {
                max_batch: 2,
                pool_pages: Some(8),
                speculate_k: 4,
                ..EngineOptions::default()
            },
        );
        let gen = Generator::dense(&model);
        let prompt = vec![4u8, 8, 15];
        let rx = eng.submit(EngineRequest {
            id: 1,
            prompt: prompt.clone(),
            max_new: 10,
            prefix_id: None,
            speculate_k: None, // engine default (4) applies
            priority: 0,
            sampling: Default::default(),
        });
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert!(resp.error.is_none());
        assert_eq!(resp.tokens, gen.generate(&prompt, 10));
        // An explicit 0 opts out and still matches.
        let rx = eng.submit(EngineRequest {
            id: 2,
            prompt: prompt.clone(),
            max_new: 10,
            prefix_id: None,
            speculate_k: Some(0),
            priority: 0,
            sampling: Default::default(),
        });
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(resp.tokens, gen.generate(&prompt, 10));
        let m = eng.metrics();
        eng.stop();
        eng.join();
        let drafted = m.tokens_drafted.load(Ordering::Relaxed);
        let accepted = m.tokens_accepted.load(Ordering::Relaxed);
        assert!(drafted > 0);
        assert_eq!(drafted, accepted, "self-draft must accept everything");
        assert!((m.acceptance_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cold_prefix_cache_evicted_under_pressure() {
        // Pool of 4 pages; two registered 40-token prefixes each pin 2
        // pages when cached. Serving a request against prefix B while
        // A's cache is cold (no live forks) must unpin A instead of
        // failing or preempting.
        let model = Arc::new(two_page_model(13));
        let eng = NativeEngine::start_with_pool(model.clone(), None, 2, 4);
        let gen = Generator::dense(&model);
        let pfx_a: Vec<u8> = (0..40).map(|i| ((i * 3 + 1) % 60) as u8).collect();
        let pfx_b: Vec<u8> = (0..40).map(|i| ((i * 5 + 2) % 60) as u8).collect();
        assert!(eng.register_prefix(1, pfx_a.clone()));
        assert!(eng.register_prefix(2, pfx_b.clone()));
        for (pid, pfx) in [(1u64, &pfx_a), (2u64, &pfx_b)] {
            let mut prompt = pfx.clone();
            prompt.push(9);
            let rx = eng.submit(EngineRequest {
                id: pid,
                prompt: prompt.clone(),
                max_new: 4,
                prefix_id: Some(pid),
                speculate_k: None,
                priority: 0,
                sampling: Default::default(),
            });
            let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
            assert!(resp.error.is_none(), "prefix {pid}: {:?}", resp.error);
            assert_eq!(resp.tokens, gen.generate(&prompt, 4), "prefix {pid} diverged");
        }
        let m = eng.metrics();
        eng.stop();
        eng.join();
        assert_eq!(m.prefix_hits.load(Ordering::Relaxed), 2);
        assert!(
            m.prefix_evictions.load(Ordering::Relaxed) >= 1,
            "building B's cache should have evicted cold A"
        );
        // Only the most recent cache (B) stays resident.
        assert_eq!(m.pages_in_use.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn perseq_attn_mode_matches_fused() {
        let model = Arc::new(two_page_model(14));
        let gen = Generator::dense(&model);
        let run = |attn_mode: AttnMode| -> Vec<Vec<u8>> {
            let eng = NativeEngine::start_with_opts(
                model.clone(),
                None,
                EngineOptions {
                    max_batch: 3,
                    attn_mode,
                    ..EngineOptions::default()
                },
            );
            let mut rxs = Vec::new();
            for i in 0..3u64 {
                rxs.push(eng.submit(EngineRequest {
                    id: i,
                    prompt: vec![(3 + i) as u8, 1, 2],
                    max_new: 8,
                    prefix_id: None,
                    speculate_k: None,
                    priority: 0,
                    sampling: Default::default(),
                }));
            }
            let out = rxs
                .into_iter()
                .map(|rx| rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap().tokens)
                .collect();
            eng.stop();
            eng.join();
            out
        };
        let fused = run(AttnMode::Fused);
        let perseq = run(AttnMode::PerSeq);
        assert_eq!(fused, perseq, "attention mode changed engine output");
        for (i, toks) in fused.iter().enumerate() {
            assert_eq!(toks, &gen.generate(&[(3 + i) as u8, 1, 2], 8));
        }
    }

    #[test]
    fn kv_quant_hot_tail_only_is_exact() {
        // 43 total rows stay inside the hot tail (quantization starts at
        // len ≥ 2 pages with kv_hot_pages = 1), so a --kv-bits engine
        // with a short sequence never builds a cold page and must be
        // bit-exact with fp32 greedy decode.
        let model = Arc::new(two_page_model(15));
        let gen = Generator::dense(&model);
        let eng = NativeEngine::start_with_opts(
            model.clone(),
            None,
            EngineOptions {
                max_batch: 2,
                kv_bits: 2,
                ..EngineOptions::default()
            },
        );
        let prompt = vec![4u8, 8, 15];
        let rx = eng.submit(EngineRequest {
            id: 1,
            prompt: prompt.clone(),
            max_new: 40,
            prefix_id: None,
            speculate_k: None,
            priority: 0,
            sampling: Default::default(),
        });
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert!(resp.error.is_none());
        assert_eq!(resp.tokens, gen.generate(&prompt, 40));
        let m = eng.metrics();
        eng.stop();
        eng.join();
        assert_eq!(m.kv_pages_quantized.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn spilled_sequences_restore_without_reprefill() {
        // The preemption pressure cooker with the quant tier on:
        // preemption now exports pages to the spill arena and
        // re-admission imports them back mid-stream. Two checkable
        // consequences: (1) no prompt token is ever prefilled twice,
        // (2) every response still equals offline fp32 greedy decode —
        // each sequence here spans 64 rows, and its first page only
        // leaves the hot tail on the very last advance, so no cold page
        // is ever *attended* and the spill/restore round trip is the
        // only thing under test.
        let model = Arc::new(two_page_model(16));
        let gen = Generator::dense(&model);
        let eng = NativeEngine::start_with_opts(
            model.clone(),
            None,
            EngineOptions {
                max_batch: 2,
                pool_pages: Some(2),
                kv_bits: 2,
                ..EngineOptions::default()
            },
        );
        let mut rxs = Vec::new();
        let mut prompts = Vec::new();
        for i in 0..3u64 {
            let prompt: Vec<u8> = (0..40)
                .map(|j| ((j * 3 + i as usize * 7 + 1) % 60) as u8)
                .collect();
            rxs.push(eng.submit(EngineRequest {
                id: i,
                prompt: prompt.clone(),
                max_new: 24,
                prefix_id: None,
                speculate_k: None,
                priority: 0,
                sampling: Default::default(),
            }));
            prompts.push(prompt);
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
            assert!(resp.error.is_none(), "request {i}: {:?}", resp.error);
            assert_eq!(
                resp.tokens,
                gen.generate(&prompts[i], 24),
                "request {i} diverged across spill/restore"
            );
        }
        let m = eng.metrics();
        eng.stop();
        eng.join();
        let spills = m.kv_spills.load(Ordering::Relaxed);
        assert!(spills > 0, "pool pressure never spilled");
        assert!(m.kv_restores.load(Ordering::Relaxed) > 0);
        // Every quant-mode preemption goes through the arena.
        assert_eq!(m.preemptions.load(Ordering::Relaxed), spills);
        // The whole point of the arena: restores resume mid-stream, so
        // the requeue path's re-prefill never happens.
        let total_prompt: usize = prompts.iter().map(|p| p.len()).sum();
        assert_eq!(m.prefill_tokens.load(Ordering::Relaxed) as usize, total_prompt);
        assert_eq!(m.pages_in_use.load(Ordering::Relaxed), 0);
        assert_eq!(m.kv_spilled_pages.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn kv_quant_pressure_run_matches_unconstrained() {
        // Spill→restore is exact and page quantization depends only on
        // each sequence's own length, so a pressure-cooked quantized
        // engine must emit byte-identical streams to an unconstrained
        // one — here with genuinely cold pages in the attended range
        // (128-row sequences quantize pages 0–2 while still decoding).
        let model = Arc::new(multi_page_model(17, 128));
        let run = |pool: Option<usize>| -> (Vec<Vec<u8>>, u64, u64) {
            let eng = NativeEngine::start_with_opts(
                model.clone(),
                None,
                EngineOptions {
                    max_batch: 3,
                    pool_pages: pool,
                    kv_bits: 2,
                    ..EngineOptions::default()
                },
            );
            let mut rxs = Vec::new();
            for i in 0..3u64 {
                rxs.push(eng.submit(EngineRequest {
                    id: i,
                    prompt: vec![(3 + 5 * i) as u8, (7 + i) as u8],
                    max_new: 126,
                    prefix_id: None,
                    speculate_k: None,
                    priority: 0,
                    sampling: Default::default(),
                }));
            }
            let outs: Vec<Vec<u8>> = rxs
                .into_iter()
                .map(|rx| {
                    let resp = rx
                        .recv_timeout(std::time::Duration::from_secs(120))
                        .unwrap();
                    assert!(resp.error.is_none(), "{:?}", resp.error);
                    resp.tokens
                })
                .collect();
            let m = eng.metrics();
            eng.stop();
            eng.join();
            (
                outs,
                m.kv_spills.load(Ordering::Relaxed),
                m.kv_pages_quantized.load(Ordering::Relaxed),
            )
        };
        let (constrained, spills, quantized) = run(Some(5));
        let (unconstrained, free_spills, _) = run(None);
        assert!(quantized > 0, "cold tier never engaged");
        assert!(spills > 0, "a 5-page pool should have forced spills");
        assert_eq!(free_spills, 0, "worst-case pool must never spill");
        assert_eq!(constrained, unconstrained, "spill/restore changed generated tokens");
    }

    #[test]
    fn oversized_sequence_fails_descriptively() {
        // A pool smaller than a single sequence cannot ever serve it:
        // the engine must answer with an error instead of spinning.
        let model = Arc::new(two_page_model(7));
        let eng = NativeEngine::start_with_pool(model.clone(), None, 2, 1);
        let rx = eng.submit(EngineRequest {
            id: 1,
            prompt: vec![1, 2, 3],
            max_new: 60, // needs 2 pages; pool holds 1
            prefix_id: None,
            speculate_k: None,
            priority: 0,
            sampling: Default::default(),
        });
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        let err = resp.error.expect("expected pool-too-small error");
        assert!(err.contains("KV pool too small"), "{err}");
        let m = eng.metrics();
        eng.stop();
        eng.join();
        // Mid-flight failure, not a submit-time rejection.
        assert_eq!(m.requests_failed.load(Ordering::Relaxed), 1);
        assert_eq!(m.requests_rejected.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn submit_queue_orders_by_class() {
        let req = |id: u64, priority: u8| EngineRequest {
            id,
            prompt: vec![1],
            max_new: 1,
            prefix_id: None,
            speculate_k: None,
            priority,
            sampling: Default::default(),
        };
        let mut q = SubmitQueue::new();
        let tx = || channel().0;
        // Fresh submits: FIFO within a class, higher classes first.
        q.push_back_classed((req(1, 0), tx(), Instant::now()));
        q.push_back_classed((req(2, 5), tx(), Instant::now()));
        q.push_back_classed((req(3, 0), tx(), Instant::now()));
        q.push_back_classed((req(4, 5), tx(), Instant::now()));
        // A preempted request re-enters at the front of its class but
        // never ahead of a strictly-higher class.
        q.push_front_classed((req(5, 0), tx(), Instant::now()));
        q.push_front_classed((req(6, 9), tx(), Instant::now()));
        let order: Vec<u64> = q.q.iter().map(|(r, _, _)| r.id).collect();
        assert_eq!(order, vec![6, 2, 4, 5, 1, 3]);
    }

    #[test]
    fn high_priority_jumps_the_queue() {
        // max_batch 1: A occupies the engine while B (class 0) and C
        // (class 9) wait. C was submitted last but belongs to a higher
        // class, so it is admitted — and completes — before B.
        let model = Arc::new(two_page_model(13));
        let eng = NativeEngine::start(model.clone(), None, 1);
        let gen = Generator::dense(&model);
        let rx_a = eng.submit(EngineRequest {
            id: 1,
            prompt: vec![3, 9],
            max_new: 40,
            prefix_id: None,
            speculate_k: None,
            priority: 0,
            sampling: Default::default(),
        });
        let rx_b = eng.submit(EngineRequest {
            id: 2,
            prompt: vec![5, 11],
            max_new: 5,
            prefix_id: None,
            speculate_k: None,
            priority: 0,
            sampling: Default::default(),
        });
        let rx_c = eng.submit(EngineRequest {
            id: 3,
            prompt: vec![7, 13],
            max_new: 5,
            prefix_id: None,
            speculate_k: None,
            priority: 9,
            sampling: Default::default(),
        });
        let t = std::time::Duration::from_secs(60);
        let a = rx_a.recv_timeout(t).unwrap();
        let b = rx_b.recv_timeout(t).unwrap();
        let c = rx_c.recv_timeout(t).unwrap();
        eng.stop();
        eng.join();
        // Priorities reorder waiting, never tokens.
        assert_eq!(a.tokens, gen.generate(&[3, 9], 40));
        assert_eq!(b.tokens, gen.generate(&[5, 11], 5));
        assert_eq!(c.tokens, gen.generate(&[7, 13], 5));
        assert!(
            c.latency_ms < b.latency_ms,
            "class 9 ({:.1} ms) should finish before class 0 ({:.1} ms)",
            c.latency_ms,
            b.latency_ms
        );
    }

    #[test]
    fn preemption_victimizes_the_lowest_class() {
        // Pool of 2 pages, two 2-page sequences: pressure must preempt
        // exactly one of them. A (class 0) is *older* than B (class 9) —
        // the age-only rule would evict B; the class-aware rule evicts A,
        // so the later, urgent submission finishes first. Both outputs
        // stay exact.
        let model = Arc::new(two_page_model(14));
        assert_eq!(pages_per_seq(&model.cfg), 2);
        let eng = NativeEngine::start_with_pool(model.clone(), None, 2, 2);
        let gen = Generator::dense(&model);
        let max_new = 40; // 2 + 40 rows = 2 pages per sequence
        let rx_a = eng.submit(EngineRequest {
            id: 1,
            prompt: vec![4, 6],
            max_new,
            prefix_id: None,
            speculate_k: None,
            priority: 0,
            sampling: Default::default(),
        });
        let rx_b = eng.submit(EngineRequest {
            id: 2,
            prompt: vec![8, 10],
            max_new,
            prefix_id: None,
            speculate_k: None,
            priority: 9,
            sampling: Default::default(),
        });
        let t = std::time::Duration::from_secs(60);
        let a = rx_a.recv_timeout(t).unwrap();
        let b = rx_b.recv_timeout(t).unwrap();
        let m = eng.metrics();
        eng.stop();
        eng.join();
        assert!(a.error.is_none());
        assert!(b.error.is_none());
        assert_eq!(a.tokens, gen.generate(&[4, 6], max_new));
        assert_eq!(b.tokens, gen.generate(&[8, 10], max_new));
        assert!(
            m.preemptions.load(Ordering::Relaxed) > 0,
            "pool pressure never triggered a preemption"
        );
        assert!(
            b.latency_ms < a.latency_ms,
            "class 9 ({:.1} ms) should have preempted class 0 ({:.1} ms), not the reverse",
            b.latency_ms,
            a.latency_ms
        );
    }

    #[test]
    fn kill_disconnects_instead_of_answering() {
        // A killed engine abandons in-flight work (channel disconnect,
        // never a response) and refuses later submits the same way —
        // the failure model the fleet router re-routes on.
        let model = Arc::new(two_page_model(15));
        let eng = NativeEngine::start(model.clone(), None, 2);
        let rx = eng.submit(EngineRequest {
            id: 1,
            prompt: vec![1, 2],
            max_new: 200, // long enough to still be in flight when killed
            prefix_id: None,
            speculate_k: None,
            priority: 0,
            sampling: Default::default(),
        });
        eng.kill();
        assert!(
            rx.recv_timeout(std::time::Duration::from_secs(60)).is_err(),
            "killed engine must disconnect, not answer"
        );
        let rx2 = eng.submit(EngineRequest {
            id: 2,
            prompt: vec![3, 4],
            max_new: 1,
            prefix_id: None,
            speculate_k: None,
            priority: 0,
            sampling: Default::default(),
        });
        assert!(
            rx2.recv_timeout(std::time::Duration::from_secs(5)).is_err(),
            "post-kill submit must be refused by disconnect"
        );
        eng.join();
    }
}
