//! The inference engine: request queue + continuous batcher + KV slots.
//!
//! Scheduler loop (runs on its own thread):
//!   1. admit queued requests into free KV slots (up to `max_batch`),
//!   2. one *batched* decode step across every active sequence — a single
//!      `Generator::decode_batch` call, so each packed codeword is decoded
//!      once per step and multiplied against all B sequences,
//!   3. extra prefill rounds: sequences still consuming their prompt take
//!      up to [`PREFILL_CHUNK`] tokens per step in batched slices instead
//!      of one token per step,
//!   4. retire finished sequences and answer their requests.
//! Requests join/leave at step boundaries — continuous batching.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::generation::{argmax, streamed_bytes_for_batch, Generator, KvCache};
use crate::model::Model;
use crate::qmodel::QuantizedModel;

use super::metrics::Metrics;

/// Prompt tokens a prefilling sequence may consume per scheduler step:
/// a freshly admitted prompt is absorbed in batched slices of this size
/// while decoding sequences still advance every step.
pub const PREFILL_CHUNK: usize = 8;

#[derive(Clone, Debug)]
pub struct EngineRequest {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new: usize,
}

#[derive(Clone, Debug)]
pub struct EngineResponse {
    pub id: u64,
    pub tokens: Vec<u8>,
    pub latency_ms: f64,
    pub prompt_len: usize,
}

/// Trait implemented by serving backends.
pub trait Engine: Send + Sync {
    /// Submit a request; the response arrives on the returned receiver.
    fn submit(&self, req: EngineRequest) -> Receiver<EngineResponse>;
    fn metrics(&self) -> Arc<Metrics>;
    fn stop(&self);
}

struct Active {
    req: EngineRequest,
    tx: Sender<EngineResponse>,
    cache: KvCache,
    generated: Vec<u8>,
    /// Pending prompt tokens not yet prefilled.
    pending_prompt: usize,
    last_logits: Vec<f32>,
    t0: Instant,
}

struct Shared {
    queue: Mutex<VecDeque<(EngineRequest, Sender<EngineResponse>)>>,
    stop: AtomicBool,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
}

/// Native-backend engine: owns the model (optionally quantized) and a
/// scheduler thread.
pub struct NativeEngine {
    shared: Arc<Shared>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl NativeEngine {
    /// `qm` enables the fused E8P decode path per layer.
    pub fn start(model: Arc<Model>, qm: Option<Arc<QuantizedModel>>, max_batch: usize) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            stop: AtomicBool::new(false),
            metrics: Arc::new(Metrics::new()),
            next_id: AtomicU64::new(1),
        });
        let sh = shared.clone();
        let handle = std::thread::spawn(move || {
            let generator = match &qm {
                Some(q) => Generator::quantized(&model, q),
                None => Generator::dense(&model),
            };
            let wb_split = generator.weight_bytes_split();
            let weight_bytes = wb_split.0 + wb_split.1 + wb_split.2;
            let mut active: Vec<Active> = Vec::new();
            loop {
                if sh.stop.load(Ordering::Relaxed) && active.is_empty() {
                    break;
                }
                // Admit (FIFO; the queue is a VecDeque so admission is O(1)
                // per request, not O(queue) as with Vec::remove(0)).
                {
                    let mut q = sh.queue.lock().unwrap();
                    while active.len() < max_batch {
                        let Some((req, tx)) = q.pop_front() else { break };
                        let cache = KvCache::new(&model);
                        let pending = req.prompt.len();
                        active.push(Active {
                            req,
                            tx,
                            cache,
                            generated: Vec::new(),
                            pending_prompt: pending,
                            last_logits: Vec::new(),
                            t0: Instant::now(),
                        });
                    }
                }
                if active.is_empty() {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    continue;
                }
                // One scheduler step = up to PREFILL_CHUNK batched decode
                // rounds. Round 0 advances every sequence by one token
                // (next prompt token while prefilling, argmax continuation
                // otherwise); later rounds only run sequences still in
                // prefill, so long prompts are consumed in batched slices
                // without re-decoding weights per sequence.
                for round in 0..PREFILL_CHUNK {
                    let mut sel: Vec<(&mut Active, u8)> = Vec::new();
                    let mut prefill_count = 0usize;
                    for a in active.iter_mut() {
                        if a.pending_prompt > 0 {
                            let idx = a.req.prompt.len() - a.pending_prompt;
                            a.pending_prompt -= 1;
                            prefill_count += 1;
                            let tok = a.req.prompt[idx];
                            sel.push((a, tok));
                        } else if round == 0 {
                            let t = argmax(&a.last_logits) as u8;
                            a.generated.push(t);
                            sel.push((a, t));
                        }
                    }
                    if sel.is_empty() {
                        break;
                    }
                    let toks: Vec<u8> = sel.iter().map(|(_, t)| *t).collect();
                    let logits = {
                        let mut caches: Vec<&mut KvCache> =
                            sel.iter_mut().map(|(a, _)| &mut a.cache).collect();
                        generator.decode_batch(&toks, &mut caches)
                    };
                    let batch = sel.len();
                    for ((a, _), lg) in sel.iter_mut().zip(logits) {
                        a.last_logits = lg;
                    }
                    sh.metrics.record_step(batch);
                    sh.metrics.record_prefill(prefill_count);
                    // Decode-once/multiply-many accounting: the batched
                    // kernel amortizes packed codes and dense linear
                    // weights across the round (per-lane lm_head traffic
                    // and per-BATCH_TILE code re-reads included), where a
                    // sequence-at-a-time loop streams everything per lane.
                    sh.metrics.record_decode_bytes(
                        streamed_bytes_for_batch(wb_split, batch),
                        weight_bytes * batch as u64,
                    );
                }
                // Retire.
                let ctx = model.cfg.ctx;
                active.retain_mut(|a| {
                    let done = a.pending_prompt == 0
                        && (a.generated.len() >= a.req.max_new || a.cache.len >= ctx);
                    if done {
                        let resp = EngineResponse {
                            id: a.req.id,
                            tokens: std::mem::take(&mut a.generated),
                            latency_ms: a.t0.elapsed().as_secs_f64() * 1e3,
                            prompt_len: a.req.prompt.len(),
                        };
                        sh.metrics.record_request(resp.tokens.len(), resp.latency_ms);
                        let _ = a.tx.send(resp);
                        false
                    } else {
                        true
                    }
                });
            }
        });
        NativeEngine {
            shared,
            handle: Mutex::new(Some(handle)),
        }
    }

    pub fn fresh_id(&self) -> u64 {
        self.shared.next_id.fetch_add(1, Ordering::Relaxed)
    }

    pub fn join(&self) {
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Engine for NativeEngine {
    fn submit(&self, req: EngineRequest) -> Receiver<EngineResponse> {
        let (tx, rx) = channel();
        self.shared.queue.lock().unwrap().push_back((req, tx));
        rx
    }

    fn metrics(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }

    fn stop(&self) {
        self.shared.stop.store(true, Ordering::Relaxed);
    }
}

impl Drop for NativeEngine {
    fn drop(&mut self) {
        self.stop();
        self.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests_support::tiny_model;

    #[test]
    fn engine_serves_requests() {
        let model = Arc::new(tiny_model(1));
        let eng = NativeEngine::start(model.clone(), None, 4);
        let mut rxs = Vec::new();
        for i in 0..6 {
            let rx = eng.submit(EngineRequest {
                id: i,
                prompt: vec![1, 2, 3, (i % 60) as u8],
                max_new: 5,
            });
            rxs.push(rx);
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
            assert_eq!(resp.id, i as u64);
            assert_eq!(resp.tokens.len(), 5);
        }
        let m = eng.metrics();
        assert_eq!(m.requests_completed.load(Ordering::Relaxed), 6);
        // With max_batch 4 and 6 requests, some steps must have batched >1.
        assert!(m.mean_batch() > 1.0, "mean batch {}", m.mean_batch());
        // The batched kernel amortizes weight traffic across the batch.
        assert!(m.bytes_amortization() > 1.0, "amortization {}", m.bytes_amortization());
        eng.stop();
        eng.join();
    }

    #[test]
    fn engine_matches_offline_generation() {
        let model = Arc::new(tiny_model(2));
        let eng = NativeEngine::start(model.clone(), None, 2);
        let prompt = vec![4u8, 8, 15];
        let rx = eng.submit(EngineRequest {
            id: 9,
            prompt: prompt.clone(),
            max_new: 6,
        });
        let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        let offline = Generator::dense(&model).generate(&prompt, 6);
        assert_eq!(resp.tokens, offline);
        eng.stop();
        eng.join();
    }

    #[test]
    fn chunked_prefill_matches_offline_generation() {
        // A prompt longer than PREFILL_CHUNK is consumed in batched
        // slices across scheduler steps; the generated continuation must
        // be identical to offline token-by-token generation.
        let model = Arc::new(tiny_model(3));
        let eng = NativeEngine::start(model.clone(), None, 3);
        let long_prompt: Vec<u8> = (0..(2 * PREFILL_CHUNK + 3))
            .map(|i| ((i * 11 + 5) % 60) as u8)
            .collect();
        let short_prompt = vec![7u8, 2];
        let rx_long = eng.submit(EngineRequest {
            id: 1,
            prompt: long_prompt.clone(),
            max_new: 6,
        });
        let rx_short = eng.submit(EngineRequest {
            id: 2,
            prompt: short_prompt.clone(),
            max_new: 6,
        });
        let gen = Generator::dense(&model);
        let resp_long = rx_long
            .recv_timeout(std::time::Duration::from_secs(30))
            .unwrap();
        let resp_short = rx_short
            .recv_timeout(std::time::Duration::from_secs(30))
            .unwrap();
        assert_eq!(resp_long.tokens, gen.generate(&long_prompt, 6));
        assert_eq!(resp_short.tokens, gen.generate(&short_prompt, 6));
        // Prefill accounting saw the long prompt.
        let m = eng.metrics();
        let prefill = m.prefill_tokens.load(Ordering::Relaxed) as usize;
        assert_eq!(prefill, long_prompt.len() + short_prompt.len());
        eng.stop();
        eng.join();
    }
}
