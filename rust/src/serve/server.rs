//! TCP front-end: line-delimited JSON over a listener socket.
//!
//! Protocol (one JSON object per line; see `rust/src/serve/README.md`
//! for the full field-by-field reference):
//!   {"prompt": [1,2,3], "max_new": 16, "prefix_id": 1, "speculate": 4,
//!    "priority": 0, "temperature": 0.8, "top_k": 40, "top_p": 0.95,
//!    "seed": 7}
//!       → {"id":…, "tokens":[…], "ms":…} (plus "error" on failure;
//!         "prefix_id" is optional — without it the engine auto-detects
//!         registered prefixes — "speculate" optionally sets the
//!         self-speculative draft length for this request: 0 forces
//!         plain decode, absent uses the engine default, and the
//!         response tokens are bit-identical either way —
//!         "priority" is the SLO class, 0–255, higher = more urgent:
//!         it orders queues and inverts into preemption, never changing
//!         the response tokens — and "temperature"/"top_k"/"top_p"/
//!         "seed" select seeded stochastic decode
//!         ([`crate::generation::sampling::SamplingParams`]): absent or
//!         0 temperature is greedy, and a sampled request's stream is
//!         reproducible from its seed alone, whatever replica, batch,
//!         or schedule serves it)
//!   {"cmd": "register_prefix", "id": 1, "tokens": [5,6,7]}
//!       → {"ok": true|false}  (share this prompt prefix's KV)
//!   {"cmd": "stats"}     → metrics snapshot (fleet-merged + per-replica
//!                          rows when serving through a router)
//!   {"cmd": "trace", "id": 7}
//!       → request 7's merged lifecycle trace ({"id":…, "truncated":…,
//!         "events":[…]}; see [`crate::serve::trace`]), or an "error"
//!         object when tracing is not enabled on the backend
//!   {"cmd": "shutdown"}  → stops the server
//!
//! The server is backend-agnostic over [`Engine`]: a single
//! [`super::engine::NativeEngine`] and a fleet [`super::router::Router`]
//! serve through the same connection handler.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::engine::{Engine, EngineRequest};
use crate::generation::sampling::SamplingParams;
use crate::util::json::Json;

/// Every field a generation request may carry on the wire, in protocol
/// order — the docs-drift test pins this list against the
/// `## Generation request` table in `rust/src/serve/README.md`, both
/// directions, so the documentation cannot drift from the parser
/// ([`handle_conn`] reads exactly these).
pub const REQUEST_WIRE_FIELDS: &[&str] = &[
    "prompt",
    "max_new",
    "prefix_id",
    "speculate",
    "priority",
    "temperature",
    "top_k",
    "top_p",
    "seed",
];

#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address; port 0 picks a free port.
    pub addr: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
        }
    }
}

pub struct ServerHandle {
    pub local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Unblock accept() with a dummy connection.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Start the TCP server on a background thread.
pub fn serve_blocking(engine: Arc<dyn Engine>, cfg: ServerConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr).context("binding server socket")?;
    let local_addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let next_id = Arc::new(AtomicU64::new(1));
    let thread = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop2.load(Ordering::Relaxed) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let engine = engine.clone();
            let stop3 = stop2.clone();
            let ids = next_id.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, engine, stop3, ids);
            });
        }
    });
    Ok(ServerHandle {
        local_addr,
        stop,
        thread: Some(thread),
    })
}

fn handle_conn(
    stream: TcpStream,
    engine: Arc<dyn Engine>,
    stop: Arc<AtomicBool>,
    ids: Arc<AtomicU64>,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let msg = match Json::parse(line.trim()) {
            Ok(m) => m,
            Err(e) => {
                writeln!(writer, "{}", Json::obj(vec![("error", Json::str(e.to_string()))]).emit())?;
                continue;
            }
        };
        match msg.get("cmd").as_str() {
            Some("stats") => {
                writeln!(writer, "{}", engine.stats_json().emit())?;
            }
            Some("trace") => {
                let out = match msg.get("id").as_usize() {
                    Some(id) => engine.trace_json(id as u64),
                    None => Json::obj(vec![(
                        "error",
                        Json::str("trace requires a numeric request id"),
                    )]),
                };
                writeln!(writer, "{}", out.emit())?;
            }
            Some("register_prefix") => {
                let tokens: Vec<u8> = msg
                    .get("tokens")
                    .as_arr()
                    .map(|a| a.iter().filter_map(|v| v.as_usize()).map(|v| v as u8).collect())
                    .unwrap_or_default();
                let ok = match msg.get("id").as_usize() {
                    Some(id) => engine.register_prefix(id as u64, tokens),
                    None => false,
                };
                writeln!(writer, "{}", Json::obj(vec![("ok", Json::Bool(ok))]).emit())?;
            }
            Some("shutdown") => {
                stop.store(true, Ordering::Relaxed);
                engine.stop();
                writeln!(writer, "{}", Json::obj(vec![("ok", Json::Bool(true))]).emit())?;
                return Ok(());
            }
            _ => {
                let prompt: Vec<u8> = msg
                    .get("prompt")
                    .as_arr()
                    .map(|a| a.iter().filter_map(|v| v.as_usize()).map(|v| v as u8).collect())
                    .unwrap_or_default();
                let max_new = msg.get("max_new").as_usize().unwrap_or(16);
                let prefix_id = msg.get("prefix_id").as_usize().map(|v| v as u64);
                // "speculate": draft tokens per self-speculative round
                // (0 forces plain decode; absent uses the engine
                // default). Responses are bit-identical either way.
                let speculate_k = msg.get("speculate").as_usize();
                // "priority": SLO class, clamped to u8 (higher = more
                // urgent). Orders queues and preemption, never tokens.
                let priority = msg.get("priority").as_usize().unwrap_or(0).min(255) as u8;
                // "temperature"/"top_k"/"top_p"/"seed": seeded
                // stochastic decode; absent (or 0) temperature keeps
                // the request greedy and the other fields inert.
                let sampling = SamplingParams {
                    temperature: msg.get("temperature").as_f64().unwrap_or(0.0) as f32,
                    top_k: msg.get("top_k").as_usize().unwrap_or(0),
                    top_p: msg.get("top_p").as_f64().unwrap_or(1.0) as f32,
                    seed: msg.get("seed").as_usize().unwrap_or(0) as u64,
                };
                let id = ids.fetch_add(1, Ordering::Relaxed);
                let rx = engine.submit(EngineRequest {
                    id,
                    prompt,
                    max_new,
                    prefix_id,
                    speculate_k,
                    priority,
                    sampling,
                });
                let resp = rx.recv().context("engine dropped request")?;
                let mut fields = vec![
                    ("id", Json::num(resp.id as f64)),
                    (
                        "tokens",
                        Json::Arr(resp.tokens.iter().map(|&t| Json::num(t as f64)).collect()),
                    ),
                    ("ms", Json::num(resp.latency_ms)),
                ];
                if let Some(err) = &resp.error {
                    fields.push(("error", Json::str(err.clone())));
                }
                writeln!(writer, "{}", Json::obj(fields).emit())?;
            }
        }
    }
}

/// Connection-robustness knobs for [`Client::connect_with`]. The plain
/// [`Client::connect`] uses no timeouts at all — right for tests that
/// legitimately wait on slow decodes, wrong for production callers,
/// where a dead server would hang them forever.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClientOptions {
    /// Give up connecting after this long (`None` = OS default).
    pub connect_timeout: Option<std::time::Duration>,
    /// Fail a read (i.e. a response wait) after this long (`None` =
    /// block indefinitely).
    pub read_timeout: Option<std::time::Duration>,
    /// On connection refused, sleep this long and retry **once** —
    /// rides out a server still binding its socket (`None` = no retry).
    pub retry_backoff: Option<std::time::Duration>,
}

/// Minimal blocking client for tests / examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    /// Connect with explicit robustness options ([`ClientOptions`]):
    /// bounded connect, one retry-with-backoff on connection refused,
    /// and a read timeout on every later response wait.
    pub fn connect_with(addr: std::net::SocketAddr, opts: ClientOptions) -> Result<Client> {
        let dial = || -> std::io::Result<TcpStream> {
            match opts.connect_timeout {
                Some(t) => TcpStream::connect_timeout(&addr, t),
                None => TcpStream::connect(addr),
            }
        };
        let stream = match dial() {
            Ok(s) => s,
            Err(e) if e.kind() == std::io::ErrorKind::ConnectionRefused => {
                let Some(backoff) = opts.retry_backoff else {
                    return Err(e).context("connecting");
                };
                std::thread::sleep(backoff);
                dial().context("connecting (after one retry)")?
            }
            Err(e) => return Err(e).context("connecting"),
        };
        stream.set_read_timeout(opts.read_timeout)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    pub fn request(&mut self, prompt: &[u8], max_new: usize) -> Result<(Vec<u8>, f64)> {
        self.request_with_prefix(prompt, max_new, None)
    }

    /// Like [`Client::request`], optionally pinning a registered prefix
    /// id (see [`Client::register_prefix`]) for the engine to fork
    /// instead of letting it auto-detect.
    pub fn request_with_prefix(
        &mut self,
        prompt: &[u8],
        max_new: usize,
        prefix_id: Option<u64>,
    ) -> Result<(Vec<u8>, f64)> {
        self.request_with_opts(prompt, max_new, prefix_id, None)
    }

    /// Like [`Client::request`], additionally asking the engine to
    /// self-speculate with `speculate` draft tokens per round (the
    /// response is bit-identical to plain decode; only latency
    /// changes). `None` leaves the engine default in force.
    pub fn request_speculative(
        &mut self,
        prompt: &[u8],
        max_new: usize,
        speculate: usize,
    ) -> Result<(Vec<u8>, f64)> {
        self.request_with_opts(prompt, max_new, None, Some(speculate))
    }

    /// Like [`Client::request`] at an explicit SLO class (`priority`,
    /// higher = more urgent): the request jumps queues and resists
    /// preemption ahead of lower classes, with identical tokens.
    pub fn request_priority(
        &mut self,
        prompt: &[u8],
        max_new: usize,
        priority: u8,
    ) -> Result<(Vec<u8>, f64)> {
        self.request_full(prompt, max_new, None, None, priority, None)
    }

    /// Like [`Client::request`] with seeded stochastic decode
    /// ([`SamplingParams`]): the response stream is a pure function of
    /// the request (prompt, params, seed), reproducible on any replica
    /// or schedule. Greedy params reproduce [`Client::request`].
    pub fn request_sampled(
        &mut self,
        prompt: &[u8],
        max_new: usize,
        sampling: SamplingParams,
    ) -> Result<(Vec<u8>, f64)> {
        self.request_full(prompt, max_new, None, None, 0, Some(sampling))
    }

    /// Full request form: optional prefix pin and speculation override.
    pub fn request_with_opts(
        &mut self,
        prompt: &[u8],
        max_new: usize,
        prefix_id: Option<u64>,
        speculate: Option<usize>,
    ) -> Result<(Vec<u8>, f64)> {
        self.request_full(prompt, max_new, prefix_id, speculate, 0, None)
    }

    /// Every generation-request field: prefix pin, speculation
    /// override, SLO class, and sampling controls.
    pub fn request_full(
        &mut self,
        prompt: &[u8],
        max_new: usize,
        prefix_id: Option<u64>,
        speculate: Option<usize>,
        priority: u8,
        sampling: Option<SamplingParams>,
    ) -> Result<(Vec<u8>, f64)> {
        let mut fields = vec![
            (
                "prompt",
                Json::Arr(prompt.iter().map(|&t| Json::num(t as f64)).collect()),
            ),
            ("max_new", Json::num(max_new as f64)),
        ];
        if let Some(pid) = prefix_id {
            fields.push(("prefix_id", Json::num(pid as f64)));
        }
        if let Some(k) = speculate {
            fields.push(("speculate", Json::num(k as f64)));
        }
        if priority > 0 {
            fields.push(("priority", Json::num(priority as f64)));
        }
        if let Some(s) = sampling {
            if !s.is_greedy() {
                fields.push(("temperature", Json::num(s.temperature as f64)));
                if s.top_k > 0 {
                    fields.push(("top_k", Json::num(s.top_k as f64)));
                }
                if s.top_p < 1.0 {
                    fields.push(("top_p", Json::num(s.top_p as f64)));
                }
                // JSON numbers are f64: seeds round-trip exactly up to
                // 2^53, plenty for a wire-chosen seed.
                fields.push(("seed", Json::num(s.seed as f64)));
            }
        }
        let msg = Json::obj(fields);
        writeln!(self.writer, "{}", msg.emit())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let resp = Json::parse(line.trim()).context("bad response")?;
        if let Some(err) = resp.get("error").as_str() {
            anyhow::bail!("server error: {err}");
        }
        let tokens = resp
            .get("tokens")
            .as_arr()
            .context("tokens")?
            .iter()
            .filter_map(|v| v.as_usize())
            .map(|v| v as u8)
            .collect();
        Ok((tokens, resp.get("ms").as_f64().unwrap_or(0.0)))
    }

    /// Register `tokens` as a shareable prompt prefix under `id`.
    /// Returns whether the server accepted it.
    pub fn register_prefix(&mut self, id: u64, tokens: &[u8]) -> Result<bool> {
        let msg = Json::obj(vec![
            ("cmd", Json::str("register_prefix")),
            ("id", Json::num(id as f64)),
            (
                "tokens",
                Json::Arr(tokens.iter().map(|&t| Json::num(t as f64)).collect()),
            ),
        ]);
        writeln!(self.writer, "{}", msg.emit())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let resp = Json::parse(line.trim()).context("bad response")?;
        Ok(resp.get("ok").as_bool().unwrap_or(false))
    }

    pub fn stats(&mut self) -> Result<Json> {
        writeln!(self.writer, "{}", r#"{"cmd":"stats"}"#)?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())?)
    }

    /// Fetch request `id`'s merged lifecycle trace
    /// ([`crate::serve::trace`]). The response carries an `error` field
    /// instead when tracing is not enabled on the serving backend.
    pub fn trace(&mut self, id: u64) -> Result<Json> {
        let msg = Json::obj(vec![
            ("cmd", Json::str("trace")),
            ("id", Json::num(id as f64)),
        ]);
        writeln!(self.writer, "{}", msg.emit())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())?)
    }

    pub fn shutdown(&mut self) -> Result<()> {
        writeln!(self.writer, "{}", r#"{"cmd":"shutdown"}"#)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    #[test]
    fn read_timeout_bounds_an_unresponsive_server() {
        // A listener that accepts and then never answers: without a
        // read timeout the client would hang forever on the response.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let hold = std::thread::spawn(move || {
            let (conn, _) = listener.accept().unwrap();
            // Keep the connection open, silently, until the test ends.
            std::thread::sleep(Duration::from_secs(10));
            drop(conn);
        });
        let mut client = Client::connect_with(
            addr,
            ClientOptions {
                connect_timeout: Some(Duration::from_secs(5)),
                read_timeout: Some(Duration::from_millis(100)),
                retry_backoff: None,
            },
        )
        .unwrap();
        let t0 = Instant::now();
        let res = client.request(&[1, 2, 3], 4);
        assert!(res.is_err(), "a silent server must not yield a response");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "read timeout did not bound the wait ({:?})",
            t0.elapsed()
        );
        drop(client); // let the holder thread outlive us harmlessly
        drop(hold);
    }

    #[test]
    fn connection_refused_retries_once_then_errors() {
        // Bind to learn a free port, then close it: connects are
        // refused. The client must retry exactly once (the backoff is
        // observable as elapsed time) and then surface the error
        // quickly instead of hanging.
        let addr = {
            let probe = TcpListener::bind("127.0.0.1:0").unwrap();
            probe.local_addr().unwrap()
        };
        let backoff = Duration::from_millis(50);
        let t0 = Instant::now();
        let res = Client::connect_with(
            addr,
            ClientOptions {
                connect_timeout: Some(Duration::from_secs(2)),
                read_timeout: Some(Duration::from_secs(2)),
                retry_backoff: Some(backoff),
            },
        );
        let elapsed = t0.elapsed();
        assert!(res.is_err(), "nothing listens there; connect must fail");
        assert!(
            elapsed >= backoff,
            "the retry backoff should have been observed ({elapsed:?})"
        );
        assert!(
            elapsed < Duration::from_secs(10),
            "refused connection should fail fast, not hang ({elapsed:?})"
        );
    }
}
