//! Proxy-Hessian collection (paper §2.2, §F.2): H = E[x xᵀ] over the
//! inputs each linear layer sees on a calibration stream, accumulated in
//! f64 with a small ridge for positive-definiteness.

use std::collections::BTreeMap;

use crate::linalg::Matrix;
use crate::model::{LinearHook, Model};

/// Accumulates per-layer input second moments during forward passes.
pub struct HessianCollector {
    acc: BTreeMap<String, (Matrix, usize)>,
    /// Layers to collect for (None = all).
    filter: Option<Vec<String>>,
}

impl HessianCollector {
    pub fn new(filter: Option<Vec<String>>) -> Self {
        HessianCollector {
            acc: BTreeMap::new(),
            filter,
        }
    }
}

impl LinearHook for HessianCollector {
    fn observe(&mut self, layer: &str, input: &[f32], rows: usize, cols: usize) {
        if let Some(f) = &self.filter {
            if !f.iter().any(|l| l == layer) {
                return;
            }
        }
        if layer == "lm_head" {
            return; // head stays fp16 (as in the paper)
        }
        let entry = self
            .acc
            .entry(layer.to_string())
            .or_insert_with(|| (Matrix::zeros(cols, cols), 0));
        // H += Xᵀ X (f64 accumulate), parallel over rows of H.
        let h = &mut entry.0;
        crate::util::threadpool::par_rows(&mut h.data, cols, |i, hrow| {
            for s in 0..rows {
                let xi = input[s * cols + i] as f64;
                if xi == 0.0 {
                    continue;
                }
                let xrow = &input[s * cols..(s + 1) * cols];
                for (hj, &xj) in hrow.iter_mut().zip(xrow) {
                    *hj += xi * xj as f64;
                }
            }
        });
        entry.1 += rows;
    }
}

impl HessianCollector {
    /// Finalize: H / count + ridge·mean(diag)·I, symmetrized.
    pub fn finalize(self, ridge: f64) -> BTreeMap<String, Matrix> {
        let mut out = BTreeMap::new();
        for (name, (mut h, count)) in self.acc {
            let inv = 1.0 / count.max(1) as f64;
            for v in h.data.iter_mut() {
                *v *= inv;
            }
            let n = h.rows;
            let mean_diag = (0..n).map(|i| h[(i, i)]).sum::<f64>() / n as f64;
            let eps = ridge * mean_diag.max(1e-12);
            for i in 0..n {
                h[(i, i)] += eps;
            }
            out.insert(name, h.symmetrize());
        }
        out
    }
}

/// Run the model over calibration windows and return per-layer Hessians.
pub fn collect_hessians(
    model: &Model,
    calib_tokens: &[u8],
    n_windows: usize,
    window: usize,
) -> BTreeMap<String, Matrix> {
    let mut collector = HessianCollector::new(None);
    let stride = (calib_tokens.len().saturating_sub(window)) / n_windows.max(1);
    for wdx in 0..n_windows {
        let start = wdx * stride;
        let toks = &calib_tokens[start..(start + window).min(calib_tokens.len())];
        model.forward(toks, &mut collector);
    }
    collector.finalize(1e-2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ldl::cholesky;
    use crate::model::tests_support::tiny_model;

    #[test]
    fn hessians_are_spd_and_right_shape() {
        let m = tiny_model(1);
        let tokens: Vec<u8> = (0..64).map(|i| (i * 7 % 64) as u8).collect();
        let hs = collect_hessians(&m, &tokens, 3, 16);
        assert!(!hs.is_empty());
        for name in m.cfg.linear_names() {
            let h = hs.get(&name).unwrap_or_else(|| panic!("missing {name}"));
            let (_, n_in) = m.cfg.linear_shape(&name);
            assert_eq!(h.rows, n_in);
            // SPD check via Cholesky.
            cholesky(h).unwrap_or_else(|e| panic!("{name} not SPD: {e}"));
        }
    }

    #[test]
    fn lm_head_not_collected() {
        let m = tiny_model(2);
        let tokens: Vec<u8> = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let hs = collect_hessians(&m, &tokens, 1, 8);
        assert!(!hs.contains_key("lm_head"));
    }

    #[test]
    fn hessian_scales_like_second_moment() {
        // Feeding the same window twice halves nothing: H is a mean.
        let m = tiny_model(3);
        let tokens: Vec<u8> = (0..32).map(|i| (i % 64) as u8).collect();
        let h1 = collect_hessians(&m, &tokens, 1, 16);
        let h2 = collect_hessians(&m, &tokens, 2, 16);
        // Different windows → different H, but same order of magnitude.
        let a = &h1["layers.0.wq"];
        let b = &h2["layers.0.wq"];
        let ra = a.trace();
        let rb = b.trace();
        assert!(ra > 0.0 && rb > 0.0);
        assert!(ra / rb < 10.0 && rb / ra < 10.0);
    }
}
