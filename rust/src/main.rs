//! `quipsharp` — the L3 coordinator binary.
//!
//! Subcommands:
//!   quantize  --size m --method quip#-2bit [--out path.qtz]
//!   eval      --size m --method quip#-2bit [--corpus w2] [--window 256]
//!   zeroshot  --size m --method quip#-2bit
//!   serve     --size m [--bits 2 [--ft]] [--addr 127.0.0.1:7140]
//!             [--max-batch 8] [--pool-pages N] [--attn-mode fused|perseq]
//!             [--speculate K] [--kv-bits 2|4] [--kv-hot-pages W]
//!             [--replicas N] [--route prefix|rr|least-loaded]
//!             [--trace-out FILE]
//!     --bits quantizes the served model (omit for fp32); --max-batch
//!     caps concurrent sequences (default 8); --pool-pages sets the KV
//!     pool size in 32-token-row pages — omitted, the pool is sized for
//!     the worst case (max-batch × ctx/32 pages, never preempts), while
//!     smaller values oversubscribe KV and preempt under pressure.
//!     --attn-mode A/Bs the fused cross-sequence attention walk against
//!     the per-sequence baseline (bit-exact logits either way);
//!     --speculate sets the default self-speculative draft length (the
//!     RVQ base stage drafts K tokens, the full model verifies — output
//!     unchanged, per-request override via the "speculate" field).
//!     --kv-bits quantizes *cold* KV-cache pages to E8P/RVQ codes (2 or
//!     4 bits/value; omit for fp32 KV, which stays bit-exact with
//!     previous releases) and routes preemptions through the host-side
//!     spill arena instead of restarting prefill; --kv-hot-pages sets
//!     how many recent full pages per sequence stay fp32 behind the
//!     write head (default 1).
//!     --replicas spins up N engine replicas behind an in-process
//!     router (one shared Arc'd model — packed codes are never
//!     duplicated — with a KV pool and scheduler per replica;
//!     --max-batch/--pool-pages apply per replica); --route picks the
//!     policy: "prefix" (default) sends requests to the replica whose
//!     prefix cache is hot, spilling to the least-loaded under load
//!     imbalance, "rr" round-robins, "least-loaded" follows in-flight
//!     counts. Routing never changes tokens — greedy decode is
//!     deterministic per request. Requests may carry a "priority" SLO
//!     class (higher = more urgent), honored by every replica's queue
//!     and preemption order.
//!     Request-lifecycle tracing is always on (bounded per-replica ring
//!     buffers; read one request's merged timeline with
//!     {"cmd":"trace","id":N}); --trace-out additionally appends every
//!     completed request's full trace to FILE as one JSON line.
//!     Prompt-prefix sharing is driven by the wire protocol
//!     (register_prefix / prefix_id), not by flags.
//!   export-codebook --out path.qtz      (E8P tables for cross-lang tests)
//!   runtime-info                         (PJRT platform + artifact list)

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use quipsharp::experiments::{Runner, WINDOW_NATIVE};
use quipsharp::generation::AttnMode;
use quipsharp::quant::pipeline::{Method, SwapCodebook};
use quipsharp::serve::{
    serve_blocking, EngineOptions, NativeEngine, RoutePolicy, Router, RouterOptions, ServerConfig,
    TraceConfig, Tracer,
};
use quipsharp::util::cli::Args;
use quipsharp::util::tensorio::{TensorData, TensorFile};

pub fn parse_method(label: &str) -> Result<Method> {
    Ok(match label {
        "fp16" => Method::Fp16,
        "quip#-2bit" => Method::QuipSharp { bits: 2, ft: true },
        "quip#-3bit" => Method::QuipSharp { bits: 3, ft: true },
        "quip#-4bit" => Method::QuipSharp { bits: 4, ft: true },
        "quip#-2bit-noft" => Method::QuipSharp { bits: 2, ft: false },
        "quip#-3bit-noft" => Method::QuipSharp { bits: 3, ft: false },
        "quip#-4bit-noft" => Method::QuipSharp { bits: 4, ft: false },
        "quip#-2bit-noe8" => Method::QuipSharpNoE8 { bits: 2 },
        "quip#-3bit-noe8" => Method::QuipSharpNoE8 { bits: 3 },
        "quip#-4bit-noe8" => Method::QuipSharpNoE8 { bits: 4 },
        "quip#-2bit-rfft" => Method::QuipSharpRfft { bits: 2 },
        "quip-kron-2bit" => Method::QuipKron { bits: 2 },
        "omniq-2bit" => Method::OmniquantLike { bits: 2, group: None },
        "omniq-3bit" => Method::OmniquantLike { bits: 3, group: None },
        "omniq-4bit" => Method::OmniquantLike { bits: 4, group: None },
        "omniq-2bit-g64" => Method::OmniquantLike { bits: 2, group: Some(64) },
        "awq-2bit" => Method::AwqLike { bits: 2 },
        "awq-3bit" => Method::AwqLike { bits: 3 },
        "awq-4bit" => Method::AwqLike { bits: 4 },
        "aqlm-2bit" => Method::AqlmLike { bits: 2 },
        "d4-2bit" => Method::CodebookSwap { cb: SwapCodebook::D4Two },
        "kmeans-2bit" => Method::CodebookSwap { cb: SwapCodebook::KMeansTwo },
        other => bail!("unknown method '{other}'"),
    })
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let art = args.get_or("art", "artifacts").to_string();
    match args.subcommand() {
        Some("quantize") => cmd_quantize(&args, &art),
        Some("eval") => cmd_eval(&args, &art),
        Some("zeroshot") => cmd_zeroshot(&args, &art),
        Some("serve") => cmd_serve(&args, &art),
        Some("export-codebook") => cmd_export_codebook(&args),
        Some("runtime-info") => cmd_runtime_info(&art),
        _ => {
            eprintln!(
                "usage: quipsharp <quantize|eval|zeroshot|serve|export-codebook|runtime-info> \
                 [--size s|m|l|moe|nonllama] [--method quip#-2bit|…] [--art artifacts]\n\
                 serve also takes: [--bits 2 [--ft]] [--addr 127.0.0.1:7140] [--max-batch 8] \
                 [--pool-pages N] (KV pool pages; default = worst case, smaller oversubscribes) \
                 [--attn-mode fused|perseq] [--speculate K] (self-speculative draft length) \
                 [--kv-bits 2|4] (E8P/RVQ-quantize cold KV pages; off = fp32 KV) \
                 [--kv-hot-pages W] (recent fp32 pages per sequence, default 1) \
                 [--replicas N] (engine replicas behind an in-process router) \
                 [--route prefix|rr|least-loaded] (fleet routing policy, default prefix) \
                 [--trace-out FILE] (append completed request traces as JSONL)"
            );
            Ok(())
        }
    }
}

fn cmd_quantize(args: &Args, art: &str) -> Result<()> {
    let size = args.get_or("size", "m");
    let method = parse_method(args.get_or("method", "quip#-2bit-noft"))?;
    let mut runner = Runner::new(art)?;
    let qm = runner.qmodel(size, &method)?;
    println!(
        "quantized '{size}' with {}: avg {:.3} bits/weight, mean proxy err {:.4}",
        method.label(),
        qm.avg_bits(),
        qm.mean_proxy_rel()
    );
    if let Some(out) = args.get("out") {
        let mut tf = TensorFile::new();
        for (name, ql) in &qm.layers {
            tf.insert(
                format!("{name}.w_eff"),
                TensorData::from_f32(vec![ql.m, ql.n], &ql.w_eff),
            );
            if let Some(p) = &ql.packed {
                for (s, codes) in p.stage_codes.iter().enumerate() {
                    tf.insert(
                        format!("{name}.codes{s}"),
                        TensorData::from_u16(vec![ql.m, ql.n / 8], codes),
                    );
                }
                tf.insert(format!("{name}.su"), TensorData::from_f32(vec![ql.m], &p.su));
                tf.insert(format!("{name}.sv"), TensorData::from_f32(vec![ql.n], &p.sv));
                tf.insert(
                    format!("{name}.scales"),
                    TensorData::from_f32(vec![p.stage_scales.len()], &p.stage_scales),
                );
            }
        }
        tf.save(out)?;
        println!("packed model written to {out}");
    }
    Ok(())
}

fn cmd_eval(args: &Args, art: &str) -> Result<()> {
    let size = args.get_or("size", "m");
    let method = parse_method(args.get_or("method", "fp16"))?;
    let corpus = args.get_or("corpus", "w2");
    let window = args.get_usize("window", WINDOW_NATIVE);
    let mut runner = Runner::new(art)?;
    let ppl = runner.ppl(size, &method, corpus, window)?;
    let bits = runner.bits(size, &method)?;
    println!(
        "{size} {} ({bits:.2} bits): {corpus} ppl (ctx {window}) = {ppl:.4}",
        method.label()
    );
    Ok(())
}

fn cmd_zeroshot(args: &Args, art: &str) -> Result<()> {
    let size = args.get_or("size", "m");
    let method = parse_method(args.get_or("method", "fp16"))?;
    let mut runner = Runner::new(art)?;
    for task in quipsharp::data::ZEROSHOT_TASKS {
        let acc = runner.zeroshot(size, &method, task)?;
        println!("{size} {} {task}: acc {:.1}%", method.label(), acc * 100.0);
    }
    Ok(())
}

fn cmd_serve(args: &Args, art: &str) -> Result<()> {
    let size = args.get_or("size", "m").to_string();
    let addr = args.get_or("addr", "127.0.0.1:7140").to_string();
    let max_batch = args.get_usize("max-batch", 8);
    let mut runner = Runner::new(art)?;
    let model = runner.model(&size)?;
    // --pool-pages: KV pool size in pages. Absent → the engine's own
    // worst-case default (no preemption); smaller values oversubscribe
    // KV and enable preemption/requeue.
    let pool_pages: Option<usize> = args
        .get("pool-pages")
        .map(|s| s.parse().context("--pool-pages"))
        .transpose()?;
    // --attn-mode: fused cross-sequence block walk (default) or the
    // per-sequence baseline walk, for A/B debugging — bit-exact logits
    // either way.
    let attn_mode = match args.get_or("attn-mode", "fused") {
        "fused" => AttnMode::Fused,
        "perseq" => AttnMode::PerSeq,
        other => bail!("unknown --attn-mode '{other}' (expected fused|perseq)"),
    };
    // --speculate: default self-speculative draft length for requests
    // that don't carry their own "speculate" field (0 = off).
    let speculate_k = args.get_usize("speculate", 0);
    // --kv-bits / --kv-hot-pages: E8P/RVQ compression of cold KV pages
    // (0 = fp32 KV, bit-exact with previous releases) and the per-seq
    // fp32 hot-tail width.
    let kv_bits = args.get_usize("kv-bits", 0);
    if !matches!(kv_bits, 0 | 2 | 4) {
        bail!("unknown --kv-bits '{kv_bits}' (expected 2 or 4; omit for fp32 KV)");
    }
    let kv_hot_pages = args.get_usize("kv-hot-pages", 1);
    // --replicas / --route: N engines behind the in-process fleet
    // router. --max-batch and --pool-pages apply per replica.
    let replicas = args.get_usize("replicas", 1).max(1);
    let route = RoutePolicy::parse(args.get_or("route", "prefix"))
        .with_context(|| "unknown --route (expected prefix|rr|least-loaded)")?;
    // Request-lifecycle tracing is always on (bounded rings; read via
    // {"cmd":"trace","id":N}). --trace-out additionally appends every
    // completed request's merged trace to FILE as one JSON line.
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    let tracer = Tracer::new(
        replicas,
        TraceConfig {
            jsonl: trace_out.clone(),
            ..TraceConfig::default()
        },
    )
    .context("creating --trace-out file")?;
    let opts = EngineOptions {
        max_batch,
        pool_pages,
        attn_mode,
        speculate_k,
        kv_bits,
        kv_hot_pages,
        // One replica: the engine is the front and owns the `submit`
        // event. A fleet: the router owns it; `start_replicas` rebinds
        // this template writer to each replica's own shard.
        tracer: Some(if replicas > 1 {
            tracer.writer(0)
        } else {
            tracer.writer(0).owning_submit()
        }),
    };
    let pool_desc = format!(
        "{}{}",
        pool_pages
            .map(|p| format!("KV pool {p} pages"))
            .unwrap_or_else(|| "worst-case KV pool".to_string()),
        if kv_bits > 0 {
            format!(", kv {kv_bits}-bit (hot tail {kv_hot_pages})")
        } else {
            String::new()
        }
    );
    let mode_desc = format!(
        "attn {}{}",
        if attn_mode == AttnMode::Fused { "fused" } else { "perseq" },
        if speculate_k > 0 {
            format!(", speculate k={speculate_k}")
        } else {
            String::new()
        }
    );
    let fleet_desc = if replicas > 1 {
        format!(", {replicas} replicas, route {}", route.label())
    } else {
        String::new()
    };
    let engines = if let Some(bits) = args.get("bits") {
        let bits: u8 = bits.parse().context("--bits")?;
        let ft = args.has_flag("ft");
        let qm = runner.qmodel(&size, &Method::QuipSharp { bits, ft })?;
        println!(
            "serving '{size}' quantized to {bits} bits \
             (avg {:.2} b/w, {pool_desc}, {mode_desc}{fleet_desc})",
            qm.avg_bits()
        );
        // One Arc'd model + one Arc'd set of packed codes, shared by
        // every replica — a replica's marginal cost is its KV pool.
        NativeEngine::start_replicas(qm.serving_model(), Some(qm), replicas, opts)
    } else {
        println!("serving '{size}' fp32 ({pool_desc}, {mode_desc}{fleet_desc})");
        NativeEngine::start_replicas(model.clone(), None, replicas, opts)
    };
    let engine: Arc<dyn quipsharp::serve::Engine> = if replicas > 1 {
        let fleet = engines
            .into_iter()
            .map(|e| Arc::new(e) as Arc<dyn quipsharp::serve::Engine>)
            .collect();
        Arc::new(Router::new(
            fleet,
            RouterOptions {
                policy: route,
                tracer: Some(tracer.front_writer()),
                ..RouterOptions::default()
            },
        ))
    } else {
        Arc::new(engines.into_iter().next().expect("one replica"))
    };
    if let Some(p) = &trace_out {
        println!("appending completed request traces to {}", p.display());
    }
    let handle = serve_blocking(engine, ServerConfig { addr })?;
    println!(
        "listening on {} (line-JSON; {{\"cmd\":\"shutdown\"}} to stop)",
        handle.local_addr
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_export_codebook(args: &Args) -> Result<()> {
    let out = args.get_or("out", "results/e8p_table_rust.qtz");
    let cb = quipsharp::quant::codebook::e8p::E8P::new();
    let mut tf = TensorFile::new();
    tf.insert(
        "abs_table",
        TensorData::from_f32(vec![256, 8], &cb.abs_table_f32()),
    );
    tf.insert(
        "parity",
        TensorData::from_u8(vec![256], cb.parity_table()),
    );
    // Full decode of all 2^16 codewords — golden reference against which
    // the python-side table construction is verified.
    let mut full = Vec::with_capacity(65536 * 8);
    for code in 0..=u16::MAX {
        for v in cb.decode_u16(code) {
            full.push(v as f32);
        }
    }
    tf.insert("decoded", TensorData::from_f32(vec![65536, 8], &full));
    if let Some(parent) = std::path::Path::new(out).parent() {
        std::fs::create_dir_all(parent)?;
    }
    tf.save(out)?;
    println!("E8P tables exported to {out}");
    Ok(())
}

fn cmd_runtime_info(art: &str) -> Result<()> {
    let rt = quipsharp::runtime::Runtime::new(art)?;
    println!("PJRT platform: {}", rt.platform());
    for (name, spec) in &rt.manifest.artifacts {
        println!(
            "  {name}: {} inputs, {} outputs ({})",
            spec.inputs.len(),
            spec.outputs.len(),
            spec.path
        );
    }
    Ok(())
}
