//! # QuIP# — full-system reproduction
//!
//! Rust + JAX + Pallas (three-layer, AOT via xla/PJRT) implementation of
//! *QuIP#: Even Better LLM Quantization with Hadamard Incoherence and
//! Lattice Codebooks* (Tseng, Chee, Sun, Kuleshov & De Sa, ICML 2024).
//!
//! Layer map:
//! * `quant` — the paper's contribution: RHT/RFFT incoherence processing,
//!   BlockLDLQ adaptive rounding, the E8P lattice codebook family, RVQ, and
//!   every baseline the paper compares against.
//! * `model`, `ft`, `eval`, `hessian`, `data` — the substrate: a native
//!   Llama-architecture transformer (forward + hand-written backprop for
//!   fine-tuning), calibration Hessians, perplexity/zeroshot harness, and
//!   the synthetic-language workload. `model::qlinear` holds the
//!   batch-native serving kernel: fused E8P decode that reads each 16-bit
//!   codeword once per step and multiplies it against all B sequences.
//! * `generation` — KV-cached autoregressive decode over the batched
//!   kernel: `decode_batch` / `decode_batch_paged` advance B sequences in
//!   lockstep (decode-once linear layers, one cross-sequence fused
//!   attention walk per step); `decode_one` is the batch-1 special case.
//!   `generation::paged` is the KV subsystem: a shared page pool
//!   (`KvPagePool`, fixed `PAGE_ROWS`-row pages, refcounted for
//!   copy-on-write prompt-prefix sharing), per-sequence page tables
//!   (`PagedKv`, with `fork_prefix` to alias a parent's prefix pages),
//!   and the flash-style attention kernels — `fused_batch_attention`
//!   walks each physical K/V block once per step for every sequence and
//!   head attending to it (aliased prefix pages load once, not once per
//!   fork), with per-sequence `blocked_attention` as the bit-exact
//!   baseline and chunked SIMD score/rescale/AV inner loops shared by
//!   both and by the paged and contiguous (`KvCache`) layouts alike,
//!   which keeps every decode path bit-exact. The pool optionally
//!   carries a KV compression tier (`KvQuantSpec`): full pages outside
//!   a configurable hot tail are re-encoded in place with the same
//!   E8P/RVQ codebooks as the weights (`quant::codebook::rowq`),
//!   charged at their compressed size against the pool's unit budget
//!   (so admitted concurrency rises at equal pool bytes), and decoded
//!   inline by the attention walk (`KvBlock::Quant`) through the same
//!   sign-LUT decode path as the weight matmuls.
//!   `generation::speculative` layers self-speculative decoding on top:
//!   the RVQ base stage embedded in every multi-stage quantization
//!   drafts k tokens against its own KV, the full model verifies all
//!   k + 1 positions in one chunked batched step
//!   (`decode_chunks_paged` — lanes decoupled from sequences), and
//!   greedy accept/reject truncates both KVs back to the last accepted
//!   row (`PagedKv::truncate` / `KvCache::truncate`) — bit-identical
//!   output at every draft length.
//! * `runtime`, `serve` — the L3 coordinator: PJRT execution of the
//!   AOT-lowered JAX/Pallas artifacts (behind the `pjrt` feature) and the
//!   continuous-batching inference server: VecDeque admission queue,
//!   pool-aware admission with preemption/requeue under KV pressure,
//!   registered-prefix forking (share a system prompt's KV across
//!   requests instead of re-prefilling it) with LRU eviction of cold
//!   cached prefixes under pressure, chunked prefill, batched paged
//!   decode steps, per-request self-speculative rounds (`speculate_k`),
//!   amortization + pool + sharing + speculation metrics. With
//!   `--kv-bits` set, preempted sequences *spill* their (mostly
//!   compressed) pages to a host-side arena and restore on
//!   re-admission instead of re-prefilling from scratch, and evicted
//!   registered prefixes park in the same arena.
//!
//! `ARCHITECTURE.md` at the repo root walks this stack top-down with a
//! diagram; `BENCHMARKS.md` documents the benchmark outputs.
//! * `util`, `bench`, `linalg` — offline-environment substrates (RNG, JSON,
//!   thread pool, tensor IO, bench harness, dense linear algebra).

pub mod bench;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod ft;
pub mod generation;
pub mod hessian;
pub mod qmodel;
pub mod runtime;
pub mod serve;
pub mod model;
pub mod linalg;
pub mod quant;
pub mod util;
