//! f32 tensor ops for the native transformer: blocked/parallel matmul,
//! norms, activations, RoPE, softmax. Shapes are explicit row-major
//! buffers — this is the substrate the evaluation and fine-tuning paths
//! run on, so the matmul is written to autovectorize.

use crate::util::threadpool;

/// y = x · wᵀ  — x: (r, k), w: (c, k) row-major (out,in), y: (r, c).
/// The hot matmul of the native path: parallel over output rows of y,
/// inner loops ordered for contiguous streaming of both operands.
pub fn matmul_nt(x: &[f32], w: &[f32], r: usize, k: usize, c: usize, y: &mut [f32]) {
    debug_assert_eq!(x.len(), r * k);
    debug_assert_eq!(w.len(), c * k);
    debug_assert_eq!(y.len(), r * c);
    threadpool::par_rows_work(y, c, k * c, |i, yrow| {
        let xrow = &x[i * k..(i + 1) * k];
        // 4-wide output blocking: each w row is streamed once; the compiler
        // vectorizes the k-loop.
        let mut j = 0;
        while j + 4 <= c {
            let w0 = &w[j * k..(j + 1) * k];
            let w1 = &w[(j + 1) * k..(j + 2) * k];
            let w2 = &w[(j + 2) * k..(j + 3) * k];
            let w3 = &w[(j + 3) * k..(j + 4) * k];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for t in 0..k {
                let xv = xrow[t];
                a0 += xv * w0[t];
                a1 += xv * w1[t];
                a2 += xv * w2[t];
                a3 += xv * w3[t];
            }
            yrow[j] = a0;
            yrow[j + 1] = a1;
            yrow[j + 2] = a2;
            yrow[j + 3] = a3;
            j += 4;
        }
        while j < c {
            let wrow = &w[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for t in 0..k {
                acc += xrow[t] * wrow[t];
            }
            yrow[j] = acc;
            j += 1;
        }
    });
}

/// y += x · w — x: (r, k), w: (k, c) row-major, y: (r, c). Used by
/// backward passes (grad wrt inputs: dX = dY · W with W (c_out, k) → this
/// is dY (r, c_out) times W (c_out, k) = matmul_nn).
pub fn matmul_nn_acc(x: &[f32], w: &[f32], r: usize, k: usize, c: usize, y: &mut [f32]) {
    debug_assert_eq!(x.len(), r * k);
    debug_assert_eq!(w.len(), k * c);
    debug_assert_eq!(y.len(), r * c);
    threadpool::par_rows_work(y, c, k * c, |i, yrow| {
        let xrow = &x[i * k..(i + 1) * k];
        for (t, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[t * c..(t + 1) * c];
            for (yv, &wv) in yrow.iter_mut().zip(wrow) {
                *yv += xv * wv;
            }
        }
    });
}

/// y += xᵀ · g — x: (r, k), g: (r, c), y: (k, c). Weight-gradient shape
/// (dW = dYᵀ X, but stored (out,in): dW[o,i] += Σ_s g[s,o]·x[s,i]).
pub fn matmul_tn_acc(g: &[f32], x: &[f32], r: usize, c_out: usize, k: usize, dw: &mut [f32]) {
    debug_assert_eq!(g.len(), r * c_out);
    debug_assert_eq!(x.len(), r * k);
    debug_assert_eq!(dw.len(), c_out * k);
    threadpool::par_rows(dw, k, |o, dwrow| {
        for s in 0..r {
            let gv = g[s * c_out + o];
            if gv == 0.0 {
                continue;
            }
            let xrow = &x[s * k..(s + 1) * k];
            for (d, &xv) in dwrow.iter_mut().zip(xrow) {
                *d += gv * xv;
            }
        }
    });
}

/// RMSNorm forward: y = x * w / rms(x), row-wise over (r, d).
/// Returns the per-row 1/rms for the backward pass.
pub fn rms_norm(x: &[f32], w: &[f32], r: usize, d: usize, y: &mut [f32]) -> Vec<f32> {
    let mut inv = vec![0.0f32; r];
    for i in 0..r {
        let row = &x[i * d..(i + 1) * d];
        let ms = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let s = 1.0 / (ms + 1e-6).sqrt();
        inv[i] = s;
        for j in 0..d {
            y[i * d + j] = row[j] * s * w[j];
        }
    }
    inv
}

/// LayerNorm forward (non-llama variant). Returns (mean, inv_std) rows.
pub fn layer_norm(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    r: usize,
    d: usize,
    y: &mut [f32],
) -> (Vec<f32>, Vec<f32>) {
    let mut means = vec![0.0f32; r];
    let mut invs = vec![0.0f32; r];
    for i in 0..r {
        let row = &x[i * d..(i + 1) * d];
        let mu = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let s = 1.0 / (var + 1e-6).sqrt();
        means[i] = mu;
        invs[i] = s;
        for j in 0..d {
            y[i * d + j] = (row[j] - mu) * s * w[j] + b[j];
        }
    }
    (means, invs)
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

#[inline]
pub fn silu_grad(x: f32) -> f32 {
    let s = 1.0 / (1.0 + (-x).exp());
    s * (1.0 + x * (1.0 - s))
}

#[inline]
pub fn gelu(x: f32) -> f32 {
    // tanh approximation (matches jax.nn.gelu default).
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

#[inline]
pub fn gelu_grad(x: f32) -> f32 {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    let u = c * (x + 0.044715 * x * x * x);
    let t = u.tanh();
    let du = c * (1.0 + 3.0 * 0.044715 * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

/// In-place softmax over the last `n` elements of each of `r` rows.
pub fn softmax_rows(x: &mut [f32], r: usize, n: usize) {
    for i in 0..r {
        let row = &mut x[i * n..(i + 1) * n];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut z = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            z += *v;
        }
        let inv = 1.0 / z;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// RoPE tables for positions 0..max_pos with head dim hd (cos, sin), each
/// (max_pos, hd/2) — matches the jax `rope` in python/compile/model.py.
pub fn rope_tables(max_pos: usize, hd: usize) -> (Vec<f32>, Vec<f32>) {
    let half = hd / 2;
    let mut cos = vec![0.0f32; max_pos * half];
    let mut sin = vec![0.0f32; max_pos * half];
    for p in 0..max_pos {
        for j in 0..half {
            let freq = 10000.0f64.powf(-(j as f64) / half as f64);
            let ang = p as f64 * freq;
            cos[p * half + j] = ang.cos() as f32;
            sin[p * half + j] = ang.sin() as f32;
        }
    }
    (cos, sin)
}

/// Apply RoPE in place to one (heads, hd) token row at position p.
pub fn rope_apply(x: &mut [f32], heads: usize, hd: usize, p: usize, cos: &[f32], sin: &[f32]) {
    let half = hd / 2;
    for h in 0..heads {
        let row = &mut x[h * hd..(h + 1) * hd];
        for j in 0..half {
            let (c, s) = (cos[p * half + j], sin[p * half + j]);
            let (a, b) = (row[j], row[half + j]);
            row[j] = a * c - b * s;
            row[half + j] = a * s + b * c;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn matmul_nt_matches_naive() {
        let mut rng = Pcg64::new(1);
        let (r, k, c) = (7, 13, 9);
        let x = rng.gaussian_vec(r * k, 1.0);
        let w = rng.gaussian_vec(c * k, 1.0);
        let mut y = vec![0.0; r * c];
        matmul_nt(&x, &w, r, k, c, &mut y);
        for i in 0..r {
            for j in 0..c {
                let want: f32 = (0..k).map(|t| x[i * k + t] * w[j * k + t]).sum();
                assert!((y[i * c + j] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn matmul_nn_acc_matches() {
        let mut rng = Pcg64::new(2);
        let (r, k, c) = (5, 6, 8);
        let x = rng.gaussian_vec(r * k, 1.0);
        let w = rng.gaussian_vec(k * c, 1.0);
        let mut y = vec![1.0f32; r * c]; // accumulates
        matmul_nn_acc(&x, &w, r, k, c, &mut y);
        for i in 0..r {
            for j in 0..c {
                let want: f32 = 1.0 + (0..k).map(|t| x[i * k + t] * w[t * c + j]).sum::<f32>();
                assert!((y[i * c + j] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn matmul_tn_acc_matches() {
        let mut rng = Pcg64::new(3);
        let (r, co, k) = (6, 4, 5);
        let g = rng.gaussian_vec(r * co, 1.0);
        let x = rng.gaussian_vec(r * k, 1.0);
        let mut dw = vec![0.0f32; co * k];
        matmul_tn_acc(&g, &x, r, co, k, &mut dw);
        for o in 0..co {
            for i in 0..k {
                let want: f32 = (0..r).map(|s| g[s * co + o] * x[s * k + i]).sum();
                assert!((dw[o * k + i] - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn softmax_normalizes() {
        let mut x = vec![1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 2, 3);
        for row in x.chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(row.windows(2).all(|w| w[0] <= w[1]));
        }
    }

    #[test]
    fn rms_norm_unit_scale() {
        let x = vec![3.0f32, 4.0];
        let w = vec![1.0f32, 1.0];
        let mut y = vec![0.0f32; 2];
        rms_norm(&x, &w, 1, 2, &mut y);
        let rms = ((9.0 + 16.0) / 2.0f32).sqrt();
        assert!((y[0] - 3.0 / rms).abs() < 1e-4);
        assert!((y[1] - 4.0 / rms).abs() < 1e-4);
    }

    #[test]
    fn rope_preserves_norm_and_rotates() {
        let (cos, sin) = rope_tables(8, 4);
        let mut x = vec![1.0f32, 0.0, 0.0, 1.0];
        let orig = x.clone();
        rope_apply(&mut x, 1, 4, 3, &cos, &sin);
        let n0: f32 = orig.iter().map(|v| v * v).sum();
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-5);
        assert!(x != orig);
        // Position 0 is identity.
        let mut y = orig.clone();
        rope_apply(&mut y, 1, 4, 0, &cos, &sin);
        assert_eq!(y, orig);
    }

    #[test]
    fn activation_grads_match_finite_difference() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.7, 3.0] {
            let eps = 1e-3;
            let fd_silu = (silu(x + eps) - silu(x - eps)) / (2.0 * eps);
            assert!((fd_silu - silu_grad(x)).abs() < 1e-3, "silu at {x}");
            let fd_gelu = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            assert!((fd_gelu - gelu_grad(x)).abs() < 1e-3, "gelu at {x}");
        }
    }
}
