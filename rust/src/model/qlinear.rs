//! The inference hot path: fused E8P decode + matvec with RHT
//! (paper Algorithm 2 / §6.3, the CUDA kernel's CPU counterpart).
//!
//! Per token: y = S_u ⊙ H_mᵀ( Σ_s scale_s · Ŵ_s · (H_n (S_v ⊙ x)) ),
//! where each Ŵ_s row is decoded on the fly from 16-bit codewords via a
//! 256×8 f32 abs-value LUT (1 KiB at 4-bit entries in the paper; 8 KiB as
//! f32 here — still L1-resident) plus branch-free sign/parity/shift bit
//! arithmetic. Memory traffic per row is 2 bytes/weight at 2 bits —
//! the memory-bound decode throughput Table 5/6 measure.
//!
//! The kernel is *batch-native*: `matmul` decodes each codeword exactly
//! once per step and multiplies it against all B right-hand sides, so the
//! memory-bound decode cost is amortized 1/B per sequence (`matvec` is
//! the B = 1 special case). The codeword payload is held behind an `Arc`
//! and the decode tables behind a process-wide shared handle, so building
//! a generator over a packed model copies no weight data.
//!
//! # Decode-once tiling invariants
//!
//! Lanes are processed in [`BATCH_TILE`]-wide tiles: within a tile each
//! 16-bit codeword is decoded into its 8 f32 weights exactly once and
//! accumulated against every lane, so a batch of B ≤ `BATCH_TILE` reads
//! the code stream exactly once per step and a larger batch reads it
//! `⌈B / BATCH_TILE⌉` times (the figure
//! [`crate::generation::streamed_bytes_for_batch`] accounts for). Two
//! orderings are load-bearing and pinned by tests:
//!
//! * **Per-lane accumulation order is batch-invariant.** A lane's dot
//!   product accumulates codeword-by-codeword in the same order at every
//!   tile width (the `bw = 1` special case included), which is why
//!   batched, paged, and sequential decode produce bit-identical logits
//!   rather than merely close ones.
//! * **Sign application is chunked, not branched.** `decode8`'s sign
//!   loop runs over fixed-width slices for autovectorization, with
//!   `decode8_scalar` kept as the bit-parity oracle over all 2¹⁶ codes.
//!   [`decode8_fast`] upgrades it to an AVX2 sign-LUT kernel when the CPU
//!   supports it (runtime-detected, scalar fallback, still bit-exact).
//! * **Parallel sharding is by whole output rows.** The kernels shard
//!   L2-sized row tiles across the persistent worker pool
//!   ([`crate::util::threadpool`]); each row has exactly one writer and
//!   its accumulation order is fixed, so results are bit-identical at any
//!   `QUIPSHARP_THREADS`, including 1.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use crate::linalg::hadamard::fwht_f32;
use crate::quant::codebook::e8p::E8P;
use crate::util::threadpool;

/// Process-wide count of codeword decodes issued by the matmul kernels
/// ([`decode8_fast`] invocations), including the `⌈B / BATCH_TILE⌉`
/// re-decodes a wide batch pays per codeword. Serving metrics snapshot
/// this per step so the BATCH_TILE re-decode cost is observable before
/// anyone tunes the tile width.
static CODEWORDS_DECODED: AtomicU64 = AtomicU64::new(0);

/// Total codewords decoded by [`QuantMatvec::matmul`]/[`QuantMatvec::matvec`]
/// (and their `_tilde` cores) since process start. Monotonic; read with
/// relaxed ordering — callers diff successive snapshots.
pub fn codewords_decoded() -> u64 {
    CODEWORDS_DECODED.load(Ordering::Relaxed)
}

/// Decode tables in hot-path layout.
pub struct E8PTables {
    /// 256 × 8 absolute values.
    pub abs: Vec<f32>,
    /// `parity[i]` = 1 when an odd number of sign flips is required.
    pub parity: [u8; 256],
    /// 256 × 8 precomputed sign masks indexed by the resolved 8-bit sign
    /// pattern (`full_bits`): entry `[bits·8 + j] = ((bits >> j) & 1) << 31`.
    /// The SIMD decode path XORs one row of this table against the abs row
    /// in a single vector op instead of materializing masks per codeword.
    pub sign_masks: Vec<u32>,
}

static SHARED_TABLES: OnceLock<E8PTables> = OnceLock::new();

impl E8PTables {
    pub fn new() -> Self {
        let cb = E8P::new();
        let abs = cb.abs_table_f32();
        let mut parity = [0u8; 256];
        for (i, &p) in cb.parity_table().iter().enumerate() {
            parity[i] = p;
        }
        let mut sign_masks = vec![0u32; 256 * 8];
        for bits in 0..256usize {
            for j in 0..8 {
                sign_masks[bits * 8 + j] = (((bits >> j) & 1) as u32) << 31;
            }
        }
        E8PTables {
            abs,
            parity,
            sign_masks,
        }
    }

    /// Process-wide shared tables: the 8 KiB LUT is identical for every
    /// layer, so every `QuantMatvec` borrows one copy instead of building
    /// its own.
    pub fn shared() -> &'static E8PTables {
        SHARED_TABLES.get_or_init(E8PTables::new)
    }
}

impl Default for E8PTables {
    fn default() -> Self {
        Self::new()
    }
}

/// Decode one 16-bit codeword into 8 f32 weights (branch-free except the
/// LUT loads). `out` must have length ≥ 8.
///
/// The sign-application loop iterates fixed-width 8-element chunks
/// (bounds hoisted out, sign masks precomputed into a stack array) so
/// the compiler can autovectorize it into a masked-XOR + add over one
/// SIMD register — the CPU counterpart of the paper's shuffle-based
/// sign application. Bit-exact with [`decode8_scalar`].
#[inline(always)]
pub fn decode8(tables: &E8PTables, code: u16, out: &mut [f32]) {
    let s_idx = (code & 0xff) as usize;
    let sign_bits = ((code >> 8) & 0x7f) as u32;
    let shift = if code & 0x8000 != 0 { 0.25f32 } else { -0.25f32 };
    let parity = tables.parity[s_idx] as u32;
    let flip7 = (sign_bits.count_ones() & 1) ^ parity; // 1 → negate coord 7
    let full_bits = sign_bits | (flip7 << 7);
    // Fixed-size chunks: one bounds check each, then a branch-free lane
    // loop over (abs, sign-mask) pairs.
    let abs: &[f32; 8] = tables.abs[s_idx * 8..s_idx * 8 + 8].try_into().unwrap();
    let out: &mut [f32] = &mut out[..8];
    let mut masks = [0u32; 8];
    for (j, m) in masks.iter_mut().enumerate() {
        *m = ((full_bits >> j) & 1) << 31;
    }
    for ((o, &a), &m) in out.iter_mut().zip(abs).zip(&masks) {
        *o = f32::from_bits(a.to_bits() ^ m) + shift;
    }
}

/// Scalar reference decode — the pre-vectorization loop, kept as the
/// parity oracle for [`decode8`].
pub fn decode8_scalar(tables: &E8PTables, code: u16, out: &mut [f32]) {
    let s_idx = (code & 0xff) as usize;
    let sign_bits = ((code >> 8) & 0x7f) as u32;
    let shift = if code & 0x8000 != 0 { 0.25f32 } else { -0.25f32 };
    let parity = tables.parity[s_idx] as u32;
    let flip7 = (sign_bits.count_ones() & 1) ^ parity;
    let abs = &tables.abs[s_idx * 8..s_idx * 8 + 8];
    let full_bits = sign_bits | (flip7 << 7);
    for j in 0..8 {
        let neg = (full_bits >> j) & 1;
        let a = abs[j];
        let signed = f32::from_bits(a.to_bits() ^ (neg << 31));
        out[j] = signed + shift;
    }
}

#[cfg(target_arch = "x86_64")]
mod simd {
    //! AVX2 `decode8` specialization: the abs row and the precomputed
    //! sign-mask row ([`E8PTables::sign_masks`]) are loaded as one 8-lane
    //! vector each, signs applied with a single XOR and the grid shift with
    //! a single broadcast add — the CPU analogue of the paper kernel's
    //! shuffle-based sign application. Every FP operation (bitwise XOR,
    //! one round-to-nearest add per lane) is identical to the scalar loop,
    //! so the result is bit-exact with [`super::decode8`].

    use super::E8PTables;

    /// # Safety
    ///
    /// Caller must have verified AVX2 support at runtime and `out.len() ≥ 8`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn decode8_avx2(tables: &E8PTables, code: u16, out: &mut [f32]) {
        use std::arch::x86_64::*;
        let s_idx = (code & 0xff) as usize;
        let sign_bits = ((code >> 8) & 0x7f) as u32;
        let shift = if code & 0x8000 != 0 { 0.25f32 } else { -0.25f32 };
        let parity = tables.parity[s_idx] as u32;
        let flip7 = (sign_bits.count_ones() & 1) ^ parity;
        let full_bits = (sign_bits | (flip7 << 7)) as usize;
        let abs = _mm256_loadu_ps(tables.abs.as_ptr().add(s_idx * 8));
        let masks =
            _mm256_loadu_si256(tables.sign_masks.as_ptr().add(full_bits * 8) as *const __m256i);
        let signed = _mm256_xor_ps(abs, _mm256_castsi256_ps(masks));
        let dec = _mm256_add_ps(signed, _mm256_set1_ps(shift));
        _mm256_storeu_ps(out.as_mut_ptr(), dec);
    }
}

/// One-shot runtime feature check for the SIMD decode path. Set
/// `QUIPSHARP_NO_SIMD=1` (before first decode) to force the chunked scalar
/// loop, e.g. for kernel A/B benchmarking.
#[cfg(target_arch = "x86_64")]
fn decode8_use_avx2() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    static STATE: AtomicU8 = AtomicU8::new(0); // 0 = unknown, 1 = no, 2 = yes
    match STATE.load(Ordering::Relaxed) {
        2 => true,
        1 => false,
        _ => {
            let yes = std::env::var_os("QUIPSHARP_NO_SIMD").is_none()
                && is_x86_feature_detected!("avx2");
            STATE.store(if yes { 2 } else { 1 }, Ordering::Relaxed);
            yes
        }
    }
}

/// Name of the decode kernel [`decode8_fast`] dispatches to on this
/// machine, for bench metadata.
pub fn decode8_kernel_name() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    {
        if decode8_use_avx2() {
            return "avx2-sign-lut";
        }
    }
    "scalar-chunked"
}

/// Decode one codeword with the best kernel available: the AVX2 sign-LUT
/// specialization when the CPU supports it (detected once at runtime),
/// falling back to the chunked autovectorized loop ([`decode8`]). Both
/// paths are bit-exact with [`decode8_scalar`]. `out` must have length ≥ 8.
#[inline(always)]
pub fn decode8_fast(tables: &E8PTables, code: u16, out: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        if decode8_use_avx2() {
            assert!(out.len() >= 8);
            // SAFETY: AVX2 verified by `decode8_use_avx2`; length checked.
            unsafe { simd::decode8_avx2(tables, code, out) };
            return;
        }
    }
    decode8(tables, code, out);
}

/// Batch lanes processed per decode: codewords are decoded once per tile,
/// so any batch up to this width pays exactly one decode per codeword.
pub const BATCH_TILE: usize = 16;

/// Row-tile payload budget for the parallel decode kernels: each stolen
/// tile's packed codes span at most this many bytes, so one tile's code
/// stream stays L2-resident while its rows are decoded and re-walked per
/// RVQ stage.
const TILE_CODE_BYTES: usize = 256 << 10;

/// A packed E8P weight matrix ready for the serving hot path.
pub struct QuantMatvec {
    pub m: usize,
    pub n: usize,
    /// Per-stage codes (m × n/8), row-major — shared with the packed
    /// layer, not copied.
    pub stage_codes: Arc<Vec<Vec<u16>>>,
    pub stage_scales: Vec<f32>,
    /// RVQ stages the kernel actually decodes (≤ `stage_codes.len()`).
    /// The full count by default; a base-stage *draft* view
    /// ([`QuantMatvec::base_stage`]) truncates to 1, halving the code
    /// stream of a 4-bit (E8P ∘ E8P) layer while sharing the same
    /// payload `Arc`.
    pub active_stages: usize,
    pub su: Vec<f32>,
    pub sv: Vec<f32>,
    pub tables: &'static E8PTables,
}

impl QuantMatvec {
    pub fn from_packed(m: usize, n: usize, p: &crate::quant::pipeline::PackedE8P) -> Self {
        QuantMatvec {
            m,
            n,
            stage_codes: p.stage_codes.clone(),
            stage_scales: p.stage_scales.clone(),
            active_stages: p.stage_codes.len(),
            su: p.su.clone(),
            sv: p.sv.clone(),
            tables: E8PTables::shared(),
        }
    }

    /// The RVQ base-stage view of this matrix: decode only stage 0 —
    /// the coarse model every multi-stage RVQ quantization contains for
    /// free (paper §4.3: 4-bit = E8P ∘ E8P, so the base stage *is* the
    /// 2-bit model). Codes stay `Arc`-shared with the full-precision
    /// view; only the stage count (and therefore the streamed bytes and
    /// decode work) changes. This is the self-speculative draft model
    /// ([`crate::generation::speculative`]).
    pub fn base_stage(&self) -> QuantMatvec {
        QuantMatvec {
            m: self.m,
            n: self.n,
            stage_codes: self.stage_codes.clone(),
            stage_scales: self.stage_scales[..1].to_vec(),
            active_stages: 1,
            su: self.su.clone(),
            sv: self.sv.clone(),
            tables: self.tables,
        }
    }

    /// Bytes of quantized weights streamed per matvec (the memory-bound
    /// cost Table 5 normalizes against). A batched step streams the same
    /// bytes once for the whole batch; a base-stage draft view streams
    /// only its active stages.
    pub fn bytes_per_matvec(&self) -> u64 {
        (self.active_stages * self.m * (self.n / 8) * 2) as u64
    }

    /// y = Ŵ_eff · x, with the RHT applied on both sides — the B = 1
    /// special case of [`QuantMatvec::matmul`].
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        self.matmul(x, 1, y);
    }

    /// Batched fused decode: ys_b = Ŵ_eff · xs_b for all B right-hand
    /// sides, decoding each codeword once per step. `xs` and `ys` are
    /// sequence-major (sequence b occupies `xs[b·n..(b+1)·n]` and
    /// `ys[b·m..(b+1)·m]`). Requires m, n powers of two (pure-FWHT fast
    /// path; the serving models satisfy this; d = 384 models route
    /// through the generic path in `pipeline::QuantizedLinear::w_eff`).
    pub fn matmul(&self, xs: &[f32], batch: usize, ys: &mut [f32]) {
        assert!(batch > 0);
        assert_eq!(xs.len(), batch * self.n);
        assert_eq!(ys.len(), batch * self.m);
        assert!(self.n.is_power_of_two() && self.m.is_power_of_two());
        let inv_sqrt_n = 1.0 / (self.n as f32).sqrt();
        let inv_sqrt_m = 1.0 / (self.m as f32).sqrt();
        if batch == 1 {
            // B = 1 fast path: the interleaved layouts coincide with the
            // plain vector layouts, so transform in place into `ys` with
            // one scratch allocation (the decode_one / Table 5 hot path).
            let mut u = vec![0.0f32; self.n];
            for ((s, &xv), &sv) in u.iter_mut().zip(xs).zip(&self.sv) {
                *s = xv * sv;
            }
            fwht_f32(&mut u);
            for v in u.iter_mut() {
                *v *= inv_sqrt_n;
            }
            self.matmul_tilde(&u, 1, ys);
            fwht_f32(ys);
            for (yv, &su) in ys.iter_mut().zip(&self.su) {
                *yv *= inv_sqrt_m * su;
            }
            return;
        }
        // u_b = H_n (s_v ⊙ x_b) / sqrt(n), per sequence, scattered into an
        // n × batch interleaved layout so the decode kernel's inner loop
        // is stride-1 across batch lanes.
        let mut ut = vec![0.0f32; batch * self.n];
        let mut scratch = vec![0.0f32; self.n];
        for b in 0..batch {
            let x = &xs[b * self.n..(b + 1) * self.n];
            for ((s, &xv), &sv) in scratch.iter_mut().zip(x).zip(&self.sv) {
                *s = xv * sv;
            }
            fwht_f32(&mut scratch);
            for (j, &v) in scratch.iter().enumerate() {
                ut[j * batch + b] = v * inv_sqrt_n;
            }
        }
        // z = Σ_s scale_s · Ŵ_s u — fused decode-once/multiply-many.
        let mut z = vec![0.0f32; batch * self.m];
        self.matmul_tilde(&ut, batch, &mut z);
        // y_b = s_u ⊙ H_mᵀ z_b / sqrt(m), per sequence.
        for b in 0..batch {
            let y = &mut ys[b * self.m..(b + 1) * self.m];
            for (i, yv) in y.iter_mut().enumerate() {
                *yv = z[i * batch + b];
            }
            fwht_f32(y);
            for (yv, &su) in y.iter_mut().zip(&self.su) {
                *yv *= inv_sqrt_m * su;
            }
        }
    }

    /// z = Σ_s scale_s · Ŵ_s u (processed domain, no RHT) — the B = 1
    /// special case of [`QuantMatvec::matmul_tilde`].
    pub fn matvec_tilde(&self, u: &[f32], z: &mut [f32]) {
        self.matmul_tilde(u, 1, z);
    }

    /// Batched pure decode+GEMM kernel (the §6.3 benchmark's inner loop):
    /// `ut` is n × batch interleaved (`ut[j·batch + b]` = coordinate j of
    /// sequence b), `z` is m × batch interleaved. Each 16-bit codeword is
    /// decoded once per [`BATCH_TILE`]-lane tile and accumulated against
    /// every lane, so at serving batch sizes (≤ 16) the 2-bytes/weight
    /// code stream is read exactly once per step.
    pub fn matmul_tilde(&self, ut: &[f32], batch: usize, z: &mut [f32]) {
        assert_eq!(ut.len(), batch * self.n);
        assert_eq!(z.len(), batch * self.m);
        let nb = self.n / 8;
        let tables = self.tables;
        let stages: Vec<(&[u16], f32)> = self
            .stage_codes
            .iter()
            .take(self.active_stages)
            .map(|c| c.as_slice())
            .zip(self.stage_scales.iter().copied())
            .collect();
        // ~n·B flops per output row (decode + B dots); serial below the
        // dispatch-amortization threshold. Parallel dispatch claims
        // multi-row tiles sized so each tile's packed codes fit in L2
        // (capped so every pool participant still gets several tiles to
        // steal). Tile geometry never affects values: one writer per row.
        let work = self.n * stages.len() * batch;
        // Every row decodes `stages·nb` codewords once per BATCH_TILE-wide
        // lane tile (batch == 1 ⇒ one tile). Counted up front — tile
        // geometry is deterministic, so this equals the number of
        // decode8_fast calls the closures below will actually make.
        CODEWORDS_DECODED.fetch_add(
            (self.m * stages.len() * nb * batch.div_ceil(BATCH_TILE)) as u64,
            Ordering::Relaxed,
        );
        let row_code_bytes = stages.len() * nb * 2;
        let tile_rows = (TILE_CODE_BYTES / row_code_bytes.max(1))
            .min(self.m.div_ceil(4 * threadpool::num_threads()))
            .max(1);
        if batch == 1 {
            // Single-lane kernel (decode_one hot path). Accumulation
            // order matches the tiled path at bw = 1, keeping batched
            // and sequential decode bit-identical.
            threadpool::par_row_tiles_work(z, 1, tile_rows, work, |i, zi| {
                zi[0] = 0.0;
                for (codes, scale) in &stages {
                    let row = &codes[i * nb..(i + 1) * nb];
                    let mut acc = 0.0f32;
                    let mut dec = [0.0f32; 8];
                    for (kb, &code) in row.iter().enumerate() {
                        decode8_fast(tables, code, &mut dec);
                        let ub = &ut[kb * 8..kb * 8 + 8];
                        for j in 0..8 {
                            acc += dec[j] * ub[j];
                        }
                    }
                    zi[0] += acc * scale;
                }
            });
            return;
        }
        threadpool::par_row_tiles_work(z, batch, tile_rows, work, |i, zrow| {
            for zv in zrow.iter_mut() {
                *zv = 0.0;
            }
            for (codes, scale) in &stages {
                let row = &codes[i * nb..(i + 1) * nb];
                let mut b0 = 0;
                while b0 < batch {
                    let bw = (batch - b0).min(BATCH_TILE);
                    let mut acc = [0.0f32; BATCH_TILE];
                    let mut dec = [0.0f32; 8];
                    for (kb, &code) in row.iter().enumerate() {
                        decode8_fast(tables, code, &mut dec);
                        let base = kb * 8 * batch + b0;
                        for (j, &w) in dec.iter().enumerate() {
                            let urow = &ut[base + j * batch..base + j * batch + bw];
                            for (a, &u) in acc[..bw].iter_mut().zip(urow) {
                                *a += w * u;
                            }
                        }
                    }
                    for (zv, &a) in zrow[b0..b0 + bw].iter_mut().zip(&acc[..bw]) {
                        *zv += a * scale;
                    }
                    b0 += bw;
                }
            }
        });
    }
}

/// Dense f32 matvec baseline (the "FP16" row of Tables 5/6 — same memory
/// role, 4 bytes/weight here).
pub fn dense_matvec(w: &[f32], x: &[f32], _m: usize, n: usize, y: &mut [f32]) {
    threadpool::par_rows_work(y, 1, n, |i, yi| {
        let row = &w[i * n..(i + 1) * n];
        let mut acc = 0.0f32;
        for (a, b) in row.iter().zip(x) {
            acc += a * b;
        }
        yi[0] = acc;
    });
}

/// Batched dense baseline: each weight row is streamed once per step and
/// dotted against all B inputs. `xs`/`ys` are sequence-major, matching
/// [`QuantMatvec::matmul`].
pub fn dense_matmul(w: &[f32], xs: &[f32], m: usize, n: usize, batch: usize, ys: &mut [f32]) {
    assert!(batch > 0);
    assert_eq!(xs.len(), batch * n);
    assert_eq!(ys.len(), batch * m);
    if batch == 1 {
        dense_matvec(w, xs, m, n, ys);
        return;
    }
    let mut z = vec![0.0f32; m * batch];
    threadpool::par_rows_work(&mut z, batch, n * batch, |i, zrow| {
        let row = &w[i * n..(i + 1) * n];
        for (b, zv) in zrow.iter_mut().enumerate() {
            let x = &xs[b * n..(b + 1) * n];
            let mut acc = 0.0f32;
            for (a, xv) in row.iter().zip(x) {
                acc += a * xv;
            }
            *zv = acc;
        }
    });
    for b in 0..batch {
        for i in 0..m {
            ys[b * m + i] = z[i * batch + b];
        }
    }
}

/// "AQLM-like" matvec: unstructured fp16-class codebook of `k` entries ×
/// 8 dims (k = 2^16 → 1 MiB at fp16; here f32 for simplicity, cache
/// behaviour is the point). Random-access gathers into a table that does
/// NOT fit in L1 — Table 6's failure mode.
pub struct BigCodebookMatvec {
    pub m: usize,
    pub n: usize,
    pub codes: Vec<u16>,
    pub table: Vec<f32>, // k × 8
}

impl BigCodebookMatvec {
    pub fn random(m: usize, n: usize, k: usize, seed: u64) -> Self {
        let mut rng = crate::util::rng::Pcg64::new(seed);
        let codes = (0..m * n / 8)
            .map(|_| rng.below(k as u64) as u16)
            .collect();
        let table = rng.gaussian_vec(k * 8, 1.0);
        BigCodebookMatvec { m, n, codes, table }
    }

    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        let nb = self.n / 8;
        threadpool::par_rows_work(y, 1, self.n, |i, yi| {
            let row = &self.codes[i * nb..(i + 1) * nb];
            let mut acc = 0.0f32;
            for (b, &code) in row.iter().enumerate() {
                let entry = &self.table[code as usize * 8..code as usize * 8 + 8];
                let ub = &x[b * 8..b * 8 + 8];
                for j in 0..8 {
                    acc += entry[j] * ub[j];
                }
            }
            yi[0] = acc;
        });
    }

    /// Batched variant (Table 6 comparison stays apples-to-apples with the
    /// batch-native E8P kernel): each codebook entry is gathered once per
    /// row block and multiplied against all B inputs — but the 2 MiB table
    /// still spills L1/L2, which is the failure mode Table 6 measures.
    pub fn matmul(&self, xs: &[f32], batch: usize, ys: &mut [f32]) {
        assert!(batch > 0);
        assert_eq!(xs.len(), batch * self.n);
        assert_eq!(ys.len(), batch * self.m);
        if batch == 1 {
            self.matvec(xs, ys);
            return;
        }
        let nb = self.n / 8;
        let n = self.n;
        let mut z = vec![0.0f32; self.m * batch];
        threadpool::par_rows_work(&mut z, batch, self.n * batch, |i, zrow| {
            let row = &self.codes[i * nb..(i + 1) * nb];
            for zv in zrow.iter_mut() {
                *zv = 0.0;
            }
            for (kb, &code) in row.iter().enumerate() {
                let entry = &self.table[code as usize * 8..code as usize * 8 + 8];
                for (b, zv) in zrow.iter_mut().enumerate() {
                    let xb = &xs[b * n + kb * 8..b * n + kb * 8 + 8];
                    let mut s = 0.0f32;
                    for j in 0..8 {
                        s += entry[j] * xb[j];
                    }
                    *zv += s;
                }
            }
        });
        for b in 0..batch {
            for i in 0..self.m {
                ys[b * self.m + i] = z[i * batch + b];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ldl::random_spd;
    use crate::linalg::Matrix;
    use crate::quant::pipeline::{quantize_matrix, Method};
    use crate::util::rng::Pcg64;

    #[test]
    fn decode8_matches_codebook() {
        let tables = E8PTables::new();
        let cb = E8P::new();
        let mut rng = Pcg64::new(1);
        let mut out = [0.0f32; 8];
        for _ in 0..500 {
            let code = (rng.next_u64() & 0xffff) as u16;
            decode8(&tables, code, &mut out);
            let want = cb.decode_u16(code);
            for j in 0..8 {
                assert!(
                    (out[j] as f64 - want[j]).abs() < 1e-6,
                    "code {code:#06x} coord {j}: {} vs {}",
                    out[j],
                    want[j]
                );
            }
        }
    }

    #[test]
    fn decode8_bit_exact_with_scalar_reference() {
        // The autovectorizable chunked path must match the scalar loop
        // bit-for-bit over the entire 16-bit code space.
        let tables = E8PTables::new();
        let mut fast = [0.0f32; 8];
        let mut slow = [0.0f32; 8];
        for code in 0..=u16::MAX {
            decode8(&tables, code, &mut fast);
            decode8_scalar(&tables, code, &mut slow);
            for j in 0..8 {
                assert!(
                    fast[j].to_bits() == slow[j].to_bits(),
                    "code {code:#06x} coord {j}: {} vs {}",
                    fast[j],
                    slow[j]
                );
            }
        }
    }

    #[test]
    fn decode8_fast_bit_exact_with_chunked() {
        // The runtime-dispatched kernel (AVX2 sign-LUT where available,
        // chunked loop otherwise) must match `decode8` bit-for-bit over
        // the entire 16-bit code space.
        let tables = E8PTables::new();
        let mut fast = [0.0f32; 8];
        let mut base = [0.0f32; 8];
        for code in 0..=u16::MAX {
            decode8_fast(&tables, code, &mut fast);
            decode8(&tables, code, &mut base);
            for j in 0..8 {
                assert!(
                    fast[j].to_bits() == base[j].to_bits(),
                    "kernel {} code {code:#06x} coord {j}: {} vs {}",
                    decode8_kernel_name(),
                    fast[j],
                    base[j]
                );
            }
        }
    }

    #[test]
    fn b1_matvec_dispatches_to_pool_at_realistic_shape() {
        // Regression for the PAR_MIN_WORK tuning: a B = 1 quantized matvec
        // at a realistic layer shape (d = 256) must go parallel — the old
        // 1<<19 threshold kept it serial on any machine.
        let (m, n) = (256usize, 256);
        let nb = n / 8;
        let mut rng = Pcg64::new(9);
        let codes: Vec<u16> = (0..m * nb).map(|_| (rng.next_u64() & 0xffff) as u16).collect();
        let qm = QuantMatvec {
            m,
            n,
            stage_codes: Arc::new(vec![codes]),
            stage_scales: vec![1.0],
            active_stages: 1,
            su: vec![1.0; m],
            sv: vec![1.0; n],
            tables: E8PTables::shared(),
        };
        let x: Vec<f32> = rng.gaussian_vec(n, 1.0);
        threadpool::with_threads(2, || {
            let before = threadpool::stats().pool_jobs;
            let mut y = vec![0.0f32; m];
            qm.matvec(&x, &mut y);
            assert!(
                threadpool::stats().pool_jobs > before,
                "B=1 decode matvec stayed serial at a realistic layer shape"
            );
        });
    }

    #[test]
    fn quant_matvec_matches_dense_w_eff() {
        // The fused decode path must agree with the dense effective weight
        // produced by the pipeline.
        let mut rng = Pcg64::new(2);
        let (m, n) = (32usize, 64usize);
        let w = Matrix::gaussian(m, n, 0.05, &mut rng);
        let h = random_spd(n, 0.1, &mut rng);
        let ql = quantize_matrix(&Method::QuipSharp { bits: 2, ft: false }, &w, &h, 3).unwrap();
        let qm = QuantMatvec::from_packed(m, n, ql.packed.as_ref().unwrap());
        let x: Vec<f32> = rng.gaussian_vec(n, 1.0);
        let mut y_fast = vec![0.0f32; m];
        qm.matvec(&x, &mut y_fast);
        let mut y_dense = vec![0.0f32; m];
        dense_matvec(&ql.w_eff, &x, m, n, &mut y_dense);
        for (a, b) in y_fast.iter().zip(&y_dense) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn quant_matvec_4bit_two_stages() {
        let mut rng = Pcg64::new(3);
        let (m, n) = (16usize, 32usize);
        let w = Matrix::gaussian(m, n, 0.05, &mut rng);
        let h = random_spd(n, 0.1, &mut rng);
        let ql = quantize_matrix(&Method::QuipSharp { bits: 4, ft: false }, &w, &h, 3).unwrap();
        let qm = QuantMatvec::from_packed(m, n, ql.packed.as_ref().unwrap());
        assert_eq!(qm.stage_codes.len(), 2);
        let x: Vec<f32> = rng.gaussian_vec(n, 1.0);
        let mut y_fast = vec![0.0f32; m];
        qm.matvec(&x, &mut y_fast);
        let mut y_dense = vec![0.0f32; m];
        dense_matvec(&ql.w_eff, &x, m, n, &mut y_dense);
        for (a, b) in y_fast.iter().zip(&y_dense) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn batched_matmul_matches_looped_matvec_exactly() {
        // decode-once/multiply-many must be bit-identical to B independent
        // matvec calls: each lane's accumulation order is the same.
        let mut rng = Pcg64::new(5);
        let (m, n) = (32usize, 64usize);
        let w = Matrix::gaussian(m, n, 0.05, &mut rng);
        let h = random_spd(n, 0.1, &mut rng);
        let ql = quantize_matrix(&Method::QuipSharp { bits: 4, ft: false }, &w, &h, 3).unwrap();
        let qm = QuantMatvec::from_packed(m, n, ql.packed.as_ref().unwrap());
        for &batch in &[1usize, 2, 5, 8] {
            let xs: Vec<f32> = rng.gaussian_vec(batch * n, 1.0);
            let mut ys = vec![0.0f32; batch * m];
            qm.matmul(&xs, batch, &mut ys);
            for b in 0..batch {
                let mut y1 = vec![0.0f32; m];
                qm.matvec(&xs[b * n..(b + 1) * n], &mut y1);
                for (i, (a, bb)) in ys[b * m..(b + 1) * m].iter().zip(&y1).enumerate() {
                    assert!(
                        a.to_bits() == bb.to_bits(),
                        "batch {batch} lane {b} row {i}: {a} vs {bb}"
                    );
                }
            }
        }
    }

    #[test]
    fn dense_matmul_matches_looped_matvec() {
        let mut rng = Pcg64::new(6);
        let (m, n) = (24usize, 48usize);
        let w: Vec<f32> = rng.gaussian_vec(m * n, 0.1);
        for &batch in &[1usize, 3, 8] {
            let xs: Vec<f32> = rng.gaussian_vec(batch * n, 1.0);
            let mut ys = vec![0.0f32; batch * m];
            dense_matmul(&w, &xs, m, n, batch, &mut ys);
            for b in 0..batch {
                let mut y1 = vec![0.0f32; m];
                dense_matvec(&w, &xs[b * n..(b + 1) * n], m, n, &mut y1);
                for (a, bb) in ys[b * m..(b + 1) * m].iter().zip(&y1) {
                    assert!((a - bb).abs() < 1e-5, "{a} vs {bb}");
                }
            }
        }
    }

    #[test]
    fn big_codebook_matmul_matches_looped() {
        let (m, n) = (16usize, 32usize);
        let big = BigCodebookMatvec::random(m, n, 1 << 10, 3);
        let mut rng = Pcg64::new(7);
        for &batch in &[1usize, 4] {
            let xs: Vec<f32> = rng.gaussian_vec(batch * n, 1.0);
            let mut ys = vec![0.0f32; batch * m];
            big.matmul(&xs, batch, &mut ys);
            for b in 0..batch {
                let mut y1 = vec![0.0f32; m];
                big.matvec(&xs[b * n..(b + 1) * n], &mut y1);
                for (a, bb) in ys[b * m..(b + 1) * m].iter().zip(&y1) {
                    assert!((a - bb).abs() < 1e-5, "{a} vs {bb}");
                }
            }
        }
    }

    #[test]
    fn tables_are_shared_and_codes_not_cloned() {
        let mut rng = Pcg64::new(4);
        let (m, n) = (16usize, 32usize);
        let w = Matrix::gaussian(m, n, 0.05, &mut rng);
        let h = random_spd(n, 0.1, &mut rng);
        let ql = quantize_matrix(&Method::QuipSharp { bits: 2, ft: false }, &w, &h, 3).unwrap();
        let p = ql.packed.as_ref().unwrap();
        let a = QuantMatvec::from_packed(m, n, p);
        let b = QuantMatvec::from_packed(m, n, p);
        assert!(std::ptr::eq(a.tables, b.tables), "decode tables not shared");
        let shared = Arc::ptr_eq(&a.stage_codes, &p.stage_codes)
            && Arc::ptr_eq(&a.stage_codes, &b.stage_codes);
        assert!(shared, "codes deep-cloned instead of Arc-shared");
    }

    #[test]
    fn bytes_accounting() {
        let mut rng = Pcg64::new(4);
        let (m, n) = (16usize, 32usize);
        let w = Matrix::gaussian(m, n, 0.05, &mut rng);
        let h = random_spd(n, 0.1, &mut rng);
        let ql = quantize_matrix(&Method::QuipSharp { bits: 2, ft: false }, &w, &h, 3).unwrap();
        let qm = QuantMatvec::from_packed(m, n, ql.packed.as_ref().unwrap());
        // 2 bits/weight → m·n/4 bytes.
        assert_eq!(qm.bytes_per_matvec(), (m * n / 4) as u64);
    }
}
