//! The inference hot path: fused E8P decode + matvec with RHT
//! (paper Algorithm 2 / §6.3, the CUDA kernel's CPU counterpart).
//!
//! Per token: y = S_u ⊙ H_mᵀ( Σ_s scale_s · Ŵ_s · (H_n (S_v ⊙ x)) ),
//! where each Ŵ_s row is decoded on the fly from 16-bit codewords via a
//! 256×8 f32 abs-value LUT (1 KiB at 4-bit entries in the paper; 8 KiB as
//! f32 here — still L1-resident) plus branch-free sign/parity/shift bit
//! arithmetic. Memory traffic per row is 2 bytes/weight at 2 bits —
//! the memory-bound decode throughput Table 5/6 measure.

use crate::linalg::hadamard::fwht_f32;
use crate::quant::codebook::e8p::E8P;
use crate::util::threadpool;

/// Decode tables in hot-path layout.
pub struct E8PTables {
    /// 256 × 8 absolute values.
    pub abs: Vec<f32>,
    /// parity[i] = 1 when an odd number of sign flips is required.
    pub parity: [u8; 256],
}

impl E8PTables {
    pub fn new() -> Self {
        let cb = E8P::new();
        let abs = cb.abs_table_f32();
        let mut parity = [0u8; 256];
        for (i, &p) in cb.parity_table().iter().enumerate() {
            parity[i] = p;
        }
        E8PTables { abs, parity }
    }
}

impl Default for E8PTables {
    fn default() -> Self {
        Self::new()
    }
}

/// Decode one 16-bit codeword into 8 f32 weights (branch-free except the
/// LUT loads). `out` must have length ≥ 8.
#[inline(always)]
pub fn decode8(tables: &E8PTables, code: u16, out: &mut [f32]) {
    let s_idx = (code & 0xff) as usize;
    let sign_bits = ((code >> 8) & 0x7f) as u32;
    let shift = if code & 0x8000 != 0 { 0.25f32 } else { -0.25f32 };
    let parity = tables.parity[s_idx] as u32;
    let flip7 = (sign_bits.count_ones() & 1) ^ parity; // 1 → negate coord 7
    let abs = &tables.abs[s_idx * 8..s_idx * 8 + 8];
    // Branch-free sign application: sign bit set → negate.
    let full_bits = sign_bits | (flip7 << 7);
    for j in 0..8 {
        let neg = (full_bits >> j) & 1;
        let a = abs[j];
        let signed = f32::from_bits(a.to_bits() ^ (neg << 31));
        out[j] = signed + shift;
    }
}

/// A packed E8P weight matrix ready for the serving hot path.
pub struct QuantMatvec {
    pub m: usize,
    pub n: usize,
    /// Per-stage codes (m × n/8), row-major.
    pub stage_codes: Vec<Vec<u16>>,
    pub stage_scales: Vec<f32>,
    pub su: Vec<f32>,
    pub sv: Vec<f32>,
    pub tables: E8PTables,
}

impl QuantMatvec {
    pub fn from_packed(m: usize, n: usize, p: &crate::quant::pipeline::PackedE8P) -> Self {
        QuantMatvec {
            m,
            n,
            stage_codes: p.stage_codes.clone(),
            stage_scales: p.stage_scales.clone(),
            su: p.su.clone(),
            sv: p.sv.clone(),
            tables: E8PTables::new(),
        }
    }

    /// Bytes of quantized weights streamed per matvec (the memory-bound
    /// cost Table 5 normalizes against).
    pub fn bytes_per_matvec(&self) -> u64 {
        (self.stage_codes.len() * self.m * (self.n / 8) * 2) as u64
    }

    /// y = Ŵ_eff · x, with the RHT applied on both sides. Requires m, n
    /// powers of two (pure-FWHT fast path; the serving models satisfy
    /// this; d = 384 models route through the generic path in
    /// `pipeline::QuantizedLinear::w_eff`).
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.m);
        assert!(self.n.is_power_of_two() && self.m.is_power_of_two());
        // u = H_n (s_v ⊙ x) / sqrt(n)
        let mut u = vec![0.0f32; self.n];
        for (ui, (&xi, &si)) in u.iter_mut().zip(x.iter().zip(&self.sv)) {
            *ui = xi * si;
        }
        fwht_f32(&mut u);
        let inv_sqrt_n = 1.0 / (self.n as f32).sqrt();
        for v in u.iter_mut() {
            *v *= inv_sqrt_n;
        }
        // z = Σ_s scale_s · Ŵ_s u — fused decode+dot, parallel over rows.
        self.matvec_tilde(&u, y);
        // y = s_u ⊙ H_mᵀ z / sqrt(m)
        fwht_f32(y);
        let inv_sqrt_m = 1.0 / (self.m as f32).sqrt();
        for (yv, &su) in y.iter_mut().zip(&self.su) {
            *yv *= inv_sqrt_m * su;
        }
    }

    /// z = Σ_s scale_s · Ŵ_s u (processed domain, no RHT) — the pure
    /// decode+GEMV kernel the §6.3 benchmark times.
    pub fn matvec_tilde(&self, u: &[f32], z: &mut [f32]) {
        let nb = self.n / 8;
        let tables = &self.tables;
        let stages: Vec<(&[u16], f32)> = self
            .stage_codes
            .iter()
            .map(|c| c.as_slice())
            .zip(self.stage_scales.iter().copied())
            .collect();
        // ~n flops per output row (decode + dot); serial below the
        // spawn-amortization threshold.
        threadpool::par_rows_work(z, 1, self.n * self.stage_codes.len(), |i, zi| {
            let mut acc_total = 0.0f32;
            for (codes, scale) in &stages {
                let row = &codes[i * nb..(i + 1) * nb];
                let mut dec = [0.0f32; 8];
                let mut acc = 0.0f32;
                for (b, &code) in row.iter().enumerate() {
                    decode8(tables, code, &mut dec);
                    let ub = &u[b * 8..b * 8 + 8];
                    let mut s = 0.0f32;
                    for j in 0..8 {
                        s += dec[j] * ub[j];
                    }
                    acc += s;
                }
                acc_total += acc * scale;
            }
            zi[0] = acc_total;
        });
    }
}

/// Dense f32 matvec baseline (the "FP16" row of Tables 5/6 — same memory
/// role, 4 bytes/weight here).
pub fn dense_matvec(w: &[f32], x: &[f32], _m: usize, n: usize, y: &mut [f32]) {
    threadpool::par_rows_work(y, 1, n, |i, yi| {
        let row = &w[i * n..(i + 1) * n];
        let mut acc = 0.0f32;
        for (a, b) in row.iter().zip(x) {
            acc += a * b;
        }
        yi[0] = acc;
    });
}

/// "AQLM-like" matvec: unstructured fp16-class codebook of `k` entries ×
/// 8 dims (k = 2^16 → 1 MiB at fp16; here f32 for simplicity, cache
/// behaviour is the point). Random-access gathers into a table that does
/// NOT fit in L1 — Table 6's failure mode.
pub struct BigCodebookMatvec {
    pub m: usize,
    pub n: usize,
    pub codes: Vec<u16>,
    pub table: Vec<f32>, // k × 8
}

impl BigCodebookMatvec {
    pub fn random(m: usize, n: usize, k: usize, seed: u64) -> Self {
        let mut rng = crate::util::rng::Pcg64::new(seed);
        let codes = (0..m * n / 8)
            .map(|_| rng.below(k as u64) as u16)
            .collect();
        let table = rng.gaussian_vec(k * 8, 1.0);
        BigCodebookMatvec { m, n, codes, table }
    }

    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        let nb = self.n / 8;
        threadpool::par_rows_work(y, 1, self.n, |i, yi| {
            let row = &self.codes[i * nb..(i + 1) * nb];
            let mut acc = 0.0f32;
            for (b, &code) in row.iter().enumerate() {
                let entry = &self.table[code as usize * 8..code as usize * 8 + 8];
                let ub = &x[b * 8..b * 8 + 8];
                for j in 0..8 {
                    acc += entry[j] * ub[j];
                }
            }
            yi[0] = acc;
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ldl::random_spd;
    use crate::linalg::Matrix;
    use crate::quant::pipeline::{quantize_matrix, Method};
    use crate::util::rng::Pcg64;

    #[test]
    fn decode8_matches_codebook() {
        let tables = E8PTables::new();
        let cb = E8P::new();
        let mut rng = Pcg64::new(1);
        let mut out = [0.0f32; 8];
        for _ in 0..500 {
            let code = (rng.next_u64() & 0xffff) as u16;
            decode8(&tables, code, &mut out);
            let want = cb.decode_u16(code);
            for j in 0..8 {
                assert!(
                    (out[j] as f64 - want[j]).abs() < 1e-6,
                    "code {code:#06x} coord {j}: {} vs {}",
                    out[j],
                    want[j]
                );
            }
        }
    }

    #[test]
    fn quant_matvec_matches_dense_w_eff() {
        // The fused decode path must agree with the dense effective weight
        // produced by the pipeline.
        let mut rng = Pcg64::new(2);
        let (m, n) = (32usize, 64usize);
        let w = Matrix::gaussian(m, n, 0.05, &mut rng);
        let h = random_spd(n, 0.1, &mut rng);
        let ql = quantize_matrix(&Method::QuipSharp { bits: 2, ft: false }, &w, &h, 3).unwrap();
        let qm = QuantMatvec::from_packed(m, n, ql.packed.as_ref().unwrap());
        let x: Vec<f32> = rng.gaussian_vec(n, 1.0);
        let mut y_fast = vec![0.0f32; m];
        qm.matvec(&x, &mut y_fast);
        let mut y_dense = vec![0.0f32; m];
        dense_matvec(&ql.w_eff, &x, m, n, &mut y_dense);
        for (a, b) in y_fast.iter().zip(&y_dense) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn quant_matvec_4bit_two_stages() {
        let mut rng = Pcg64::new(3);
        let (m, n) = (16usize, 32usize);
        let w = Matrix::gaussian(m, n, 0.05, &mut rng);
        let h = random_spd(n, 0.1, &mut rng);
        let ql = quantize_matrix(&Method::QuipSharp { bits: 4, ft: false }, &w, &h, 3).unwrap();
        let qm = QuantMatvec::from_packed(m, n, ql.packed.as_ref().unwrap());
        assert_eq!(qm.stage_codes.len(), 2);
        let x: Vec<f32> = rng.gaussian_vec(n, 1.0);
        let mut y_fast = vec![0.0f32; m];
        qm.matvec(&x, &mut y_fast);
        let mut y_dense = vec![0.0f32; m];
        dense_matvec(&ql.w_eff, &x, m, n, &mut y_dense);
        for (a, b) in y_fast.iter().zip(&y_dense) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn bytes_accounting() {
        let mut rng = Pcg64::new(4);
        let (m, n) = (16usize, 32usize);
        let w = Matrix::gaussian(m, n, 0.05, &mut rng);
        let h = random_spd(n, 0.1, &mut rng);
        let ql = quantize_matrix(&Method::QuipSharp { bits: 2, ft: false }, &w, &h, 3).unwrap();
        let qm = QuantMatvec::from_packed(m, n, ql.packed.as_ref().unwrap());
        // 2 bits/weight → m·n/4 bytes.
        assert_eq!(qm.bytes_per_matvec(), (m * n / 4) as u64);
    }
}
