//! Native transformer: the evaluation substrate. Mirrors
//! `python/compile/model.py` exactly (same weight names, same math) so the
//! PJRT artifacts and the native path can be cross-checked numerically.

pub mod ops;
pub mod qlinear;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::tensorio::TensorFile;
use ops::*;

/// Architecture variants (Table 9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arch {
    Llama,
    Moe,
    NonLlama,
}

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub ctx: usize,
    pub arch: Arch,
    pub n_experts: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// The built-in family (must match python CONFIGS).
    pub fn by_name(name: &str) -> Result<ModelConfig> {
        let (d, l, h, ff, arch) = match name {
            "s" => (128, 2, 4, 512, Arch::Llama),
            "m" => (256, 4, 8, 1024, Arch::Llama),
            "l" => (384, 4, 8, 1536, Arch::Llama),
            "moe" => (128, 2, 4, 512, Arch::Moe),
            "nonllama" => (128, 2, 4, 512, Arch::NonLlama),
            _ => bail!("unknown model '{name}'"),
        };
        Ok(ModelConfig {
            name: name.to_string(),
            d_model: d,
            n_layers: l,
            n_heads: h,
            d_ff: ff,
            vocab: 256,
            ctx: 256,
            arch,
            n_experts: 2,
        })
    }

    /// Quantizable linear layers in quantization order (matches python
    /// `linear_layer_names`).
    pub fn linear_names(&self) -> Vec<String> {
        let mut out = Vec::new();
        for i in 0..self.n_layers {
            let p = format!("layers.{i}.");
            for nm in ["wq", "wk", "wv", "wo"] {
                out.push(format!("{p}{nm}"));
            }
            if self.arch == Arch::Moe {
                for e in 0..self.n_experts {
                    for nm in ["w_gate", "w_up", "w_down"] {
                        out.push(format!("{p}{nm}.{e}"));
                    }
                }
            } else {
                for nm in ["w_gate", "w_up", "w_down"] {
                    out.push(format!("{p}{nm}"));
                }
            }
        }
        out
    }

    /// (out, in) shape of a named linear layer.
    pub fn linear_shape(&self, name: &str) -> (usize, usize) {
        let field = name.rsplit('.').find(|s| s.parse::<usize>().is_err()).unwrap();
        match field {
            "wq" | "wk" | "wv" | "wo" => (self.d_model, self.d_model),
            "w_gate" | "w_up" => (self.d_ff, self.d_model),
            "w_down" => (self.d_model, self.d_ff),
            _ => panic!("not a linear: {name}"),
        }
    }
}

/// Named f32 tensor.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }
}

pub type Params = BTreeMap<String, Tensor>;

/// Observes inputs to every linear layer during a forward pass — the
/// Hessian collector (§F.2) and block fine-tuning hook into this.
pub trait LinearHook {
    fn observe(&mut self, layer: &str, input: &[f32], rows: usize, cols: usize);
}

/// A no-op hook.
pub struct NoHook;
impl LinearHook for NoHook {
    fn observe(&mut self, _: &str, _: &[f32], _: usize, _: usize) {}
}

pub struct Model {
    pub cfg: ModelConfig,
    pub params: Params,
    rope_cos: Vec<f32>,
    rope_sin: Vec<f32>,
}

impl Model {
    pub fn new(cfg: ModelConfig, params: Params) -> Self {
        let (rope_cos, rope_sin) = rope_tables(cfg.ctx, cfg.head_dim());
        Model {
            cfg,
            params,
            rope_cos,
            rope_sin,
        }
    }

    /// Load trained weights from `artifacts/model_{name}.qtz`.
    pub fn load(art_dir: impl AsRef<Path>, name: &str) -> Result<Model> {
        let cfg = ModelConfig::by_name(name)?;
        let tf = TensorFile::load(art_dir.as_ref().join(format!("model_{name}.qtz")))
            .with_context(|| format!("loading model '{name}'"))?;
        let mut params = Params::new();
        for (k, t) in &tf.tensors {
            params.insert(k.clone(), Tensor::new(t.shape.clone(), t.to_f32()?));
        }
        Ok(Model::new(cfg, params))
    }

    /// Random-weight model for a config (benchmarks and demos: weight
    /// values don't affect decode throughput). Covers every [`Arch`]
    /// variant, including MoE routers and NonLlama positional/bias
    /// parameters.
    pub fn random(cfg: ModelConfig, seed: u64) -> Model {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(seed);
        let mut params = Params::new();
        let (d, ff) = (cfg.d_model, cfg.d_ff);
        let mut dense = |m: usize, n: usize, rng: &mut Pcg64| {
            Tensor::new(vec![m, n], rng.gaussian_vec(m * n, 1.0 / (n as f32).sqrt()))
        };
        let arch = cfg.arch;
        let norm = |name: &str, params: &mut Params| {
            params.insert(name.to_string(), Tensor::new(vec![d], vec![1.0; d]));
            if arch == Arch::NonLlama {
                params.insert(format!("{name}_bias"), Tensor::new(vec![d], vec![0.0; d]));
            }
        };
        params.insert("embed".into(), dense(cfg.vocab, d, &mut rng));
        params.insert("lm_head".into(), dense(cfg.vocab, d, &mut rng));
        if arch == Arch::NonLlama {
            let pe = rng.gaussian_vec(cfg.ctx * d, 0.02);
            params.insert("pos_embed".into(), Tensor::new(vec![cfg.ctx, d], pe));
        }
        norm("final_norm", &mut params);
        for i in 0..cfg.n_layers {
            let p = format!("layers.{i}.");
            norm(&format!("{p}attn_norm"), &mut params);
            norm(&format!("{p}mlp_norm"), &mut params);
            for nm in ["wq", "wk", "wv", "wo"] {
                params.insert(format!("{p}{nm}"), dense(d, d, &mut rng));
            }
            if arch == Arch::Moe {
                params.insert(format!("{p}router"), dense(cfg.n_experts, d, &mut rng));
                for e in 0..cfg.n_experts {
                    params.insert(format!("{p}w_gate.{e}"), dense(ff, d, &mut rng));
                    params.insert(format!("{p}w_up.{e}"), dense(ff, d, &mut rng));
                    params.insert(format!("{p}w_down.{e}"), dense(d, ff, &mut rng));
                }
            } else {
                params.insert(format!("{p}w_gate"), dense(ff, d, &mut rng));
                params.insert(format!("{p}w_up"), dense(ff, d, &mut rng));
                params.insert(format!("{p}w_down"), dense(d, ff, &mut rng));
            }
        }
        Model::new(cfg, params)
    }

    pub fn p(&self, name: &str) -> &Tensor {
        self.params
            .get(name)
            .unwrap_or_else(|| panic!("missing param {name}"))
    }

    /// Replace a linear layer's dense weight (quantized swap-in).
    pub fn set_linear(&mut self, name: &str, w: Vec<f32>) {
        let t = self.params.get_mut(name).expect("unknown linear");
        assert_eq!(t.data.len(), w.len());
        t.data = w;
    }

    pub fn num_params(&self) -> usize {
        self.params.values().map(|t| t.data.len()).sum()
    }

    /// Bytes of weight data read per generated token (memory-bound decode
    /// cost model, Table 5's %-of-bandwidth denominator).
    pub fn weight_bytes(&self, bits_per_weight: f64) -> f64 {
        self.num_params() as f64 * bits_per_weight / 8.0
    }

    fn linear(
        &self,
        name: &str,
        x: &[f32],
        rows: usize,
        hook: &mut dyn LinearHook,
        y: &mut [f32],
    ) {
        let w = self.p(name);
        let (m, n) = (w.shape[0], w.shape[1]);
        hook.observe(name, x, rows, n);
        matmul_nt(x, &w.data, rows, n, m, y);
    }

    /// Full-sequence forward. Returns logits (s × vocab).
    pub fn forward(&self, tokens: &[u8], hook: &mut dyn LinearHook) -> Vec<f32> {
        let cfg = &self.cfg;
        let (s, d, heads, hd, ff) = (
            tokens.len(),
            cfg.d_model,
            cfg.n_heads,
            cfg.head_dim(),
            cfg.d_ff,
        );
        assert!(s <= cfg.ctx, "sequence {s} exceeds ctx {}", cfg.ctx);
        let embed = self.p("embed");
        let mut x = vec![0.0f32; s * d];
        for (i, &t) in tokens.iter().enumerate() {
            x[i * d..(i + 1) * d].copy_from_slice(&embed.data[t as usize * d..(t as usize + 1) * d]);
        }
        if cfg.arch == Arch::NonLlama {
            let pe = self.p("pos_embed");
            for i in 0..s {
                for j in 0..d {
                    x[i * d + j] += pe.data[i * d + j];
                }
            }
        }

        let mut h = vec![0.0f32; s * d];
        let mut qkv = vec![0.0f32; s * d];
        let mut q = vec![0.0f32; s * d];
        let mut k = vec![0.0f32; s * d];
        let mut v = vec![0.0f32; s * d];
        let mut att_out = vec![0.0f32; s * d];
        let mut ffg = vec![0.0f32; s * ff];
        let mut ffu = vec![0.0f32; s * ff];
        let mut ffd = vec![0.0f32; s * d];

        for layer in 0..cfg.n_layers {
            let pre = format!("layers.{layer}.");
            // --- attention ---
            self.norm(&format!("{pre}attn_norm"), &x, s, d, &mut h);
            self.linear(&format!("{pre}wq"), &h, s, hook, &mut q);
            self.linear(&format!("{pre}wk"), &h, s, hook, &mut k);
            self.linear(&format!("{pre}wv"), &h, s, hook, &mut v);
            if cfg.arch != Arch::NonLlama {
                for i in 0..s {
                    rope_apply(&mut q[i * d..(i + 1) * d], heads, hd, i, &self.rope_cos, &self.rope_sin);
                    rope_apply(&mut k[i * d..(i + 1) * d], heads, hd, i, &self.rope_cos, &self.rope_sin);
                }
            }
            self.attention(&q, &k, &v, s, &mut att_out);
            self.linear(&format!("{pre}wo"), &att_out, s, hook, &mut qkv);
            for (xv, &o) in x.iter_mut().zip(&qkv) {
                *xv += o;
            }
            // --- mlp ---
            self.norm(&format!("{pre}mlp_norm"), &x, s, d, &mut h);
            match cfg.arch {
                Arch::Moe => {
                    let router = self.p(&format!("{pre}router"));
                    let ne = cfg.n_experts;
                    let mut gate_logits = vec![0.0f32; s * ne];
                    matmul_nt(&h, &router.data, s, d, ne, &mut gate_logits);
                    softmax_rows(&mut gate_logits, s, ne);
                    let mut moe_acc = vec![0.0f32; s * d];
                    for e in 0..ne {
                        self.linear(&format!("{pre}w_gate.{e}"), &h, s, hook, &mut ffg);
                        self.linear(&format!("{pre}w_up.{e}"), &h, s, hook, &mut ffu);
                        for (g, &u) in ffg.iter_mut().zip(&ffu) {
                            *g = silu(*g) * u;
                        }
                        self.linear(&format!("{pre}w_down.{e}"), &ffg, s, hook, &mut ffd);
                        for i in 0..s {
                            let gw = gate_logits[i * ne + e];
                            for j in 0..d {
                                moe_acc[i * d + j] += gw * ffd[i * d + j];
                            }
                        }
                    }
                    for (xv, &o) in x.iter_mut().zip(&moe_acc) {
                        *xv += o;
                    }
                }
                _ => {
                    self.linear(&format!("{pre}w_gate"), &h, s, hook, &mut ffg);
                    self.linear(&format!("{pre}w_up"), &h, s, hook, &mut ffu);
                    if cfg.arch == Arch::NonLlama {
                        for (g, &u) in ffg.iter_mut().zip(&ffu) {
                            *g = gelu(*g) * u;
                        }
                    } else {
                        for (g, &u) in ffg.iter_mut().zip(&ffu) {
                            *g = silu(*g) * u;
                        }
                    }
                    self.linear(&format!("{pre}w_down"), &ffg, s, hook, &mut ffd);
                    for (xv, &o) in x.iter_mut().zip(&ffd) {
                        *xv += o;
                    }
                }
            }
        }
        self.norm("final_norm", &x, s, d, &mut h);
        let head = self.p("lm_head");
        let mut logits = vec![0.0f32; s * cfg.vocab];
        hook.observe("lm_head", &h, s, d);
        matmul_nt(&h, &head.data, s, d, cfg.vocab, &mut logits);
        logits
    }

    fn norm(&self, name: &str, x: &[f32], s: usize, d: usize, y: &mut [f32]) {
        match self.cfg.arch {
            Arch::NonLlama => {
                let w = self.p(name);
                let b = self.p(&format!("{name}_bias"));
                layer_norm(x, &w.data, &b.data, s, d, y);
            }
            _ => {
                let w = self.p(name);
                rms_norm(x, &w.data, s, d, y);
            }
        }
    }

    /// Multi-head causal attention over full (s, heads·hd) q/k/v buffers.
    fn attention(&self, q: &[f32], k: &[f32], v: &[f32], s: usize, out: &mut [f32]) {
        let heads = self.cfg.n_heads;
        let hd = self.cfg.head_dim();
        let d = heads * hd;
        let scale = 1.0 / (hd as f32).sqrt();
        // Parallel over heads: each head writes a disjoint column stripe
        // of `out`; gather per-head contiguous copies first.
        let out_ptr = std::sync::Mutex::new(());
        let _ = out_ptr;
        let results: Vec<Vec<f32>> = crate::util::threadpool::par_map(heads, |hh| {
            let mut qh = vec![0.0f32; s * hd];
            let mut kh = vec![0.0f32; s * hd];
            let mut vh = vec![0.0f32; s * hd];
            for i in 0..s {
                qh[i * hd..(i + 1) * hd].copy_from_slice(&q[i * d + hh * hd..i * d + (hh + 1) * hd]);
                kh[i * hd..(i + 1) * hd].copy_from_slice(&k[i * d + hh * hd..i * d + (hh + 1) * hd]);
                vh[i * hd..(i + 1) * hd].copy_from_slice(&v[i * d + hh * hd..i * d + (hh + 1) * hd]);
            }
            let mut scores = vec![0.0f32; s * s];
            matmul_nt(&qh, &kh, s, hd, s, &mut scores);
            for i in 0..s {
                for j in 0..s {
                    scores[i * s + j] = if j <= i {
                        scores[i * s + j] * scale
                    } else {
                        f32::NEG_INFINITY
                    };
                }
            }
            softmax_rows(&mut scores, s, s);
            let mut oh = vec![0.0f32; s * hd];
            matmul_nn_acc(&scores, &vh, s, s, hd, &mut oh);
            oh
        });
        for (hh, oh) in results.into_iter().enumerate() {
            for i in 0..s {
                out[i * d + hh * hd..i * d + (hh + 1) * hd].copy_from_slice(&oh[i * hd..(i + 1) * hd]);
            }
        }
    }
}

/// Test-only helpers shared across modules (hessian, ft, eval tests).
#[cfg(test)]
pub mod tests_support {
    use super::*;

    pub fn tiny_model(seed: u64) -> Model {
        // Delegates to Model::random, which draws the identical parameter
        // sequence for a Llama config (same init scale, same RNG order) —
        // seed-sensitive test expectations are unchanged.
        let cfg = ModelConfig {
            name: "tiny".into(),
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            vocab: 64,
            ctx: 32,
            arch: Arch::Llama,
            n_experts: 2,
        };
        Model::random(cfg, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::tiny_model;
    use super::*;

    #[test]
    fn forward_shapes_and_finite() {
        let m = tiny_model(1);
        let tokens: Vec<u8> = (0..16).map(|i| (i * 3 % 64) as u8).collect();
        let logits = m.forward(&tokens, &mut NoHook);
        assert_eq!(logits.len(), 16 * 64);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality_prefix_invariance() {
        // Changing a later token must not change earlier logits.
        let m = tiny_model(2);
        let mut t1: Vec<u8> = (0..12).map(|i| (i % 64) as u8).collect();
        let l1 = m.forward(&t1, &mut NoHook);
        t1[11] = 63;
        let l2 = m.forward(&t1, &mut NoHook);
        for i in 0..11 * 64 {
            assert!((l1[i] - l2[i]).abs() < 1e-5, "leak at {i}");
        }
        // And the last logits must change.
        let diff: f32 = (0..64).map(|j| (l1[11 * 64 + j] - l2[11 * 64 + j]).abs()).sum();
        assert!(diff > 1e-3);
    }

    #[test]
    fn hook_sees_all_linears() {
        struct Counter(std::collections::BTreeSet<String>);
        impl LinearHook for Counter {
            fn observe(&mut self, l: &str, _: &[f32], _: usize, _: usize) {
                self.0.insert(l.to_string());
            }
        }
        let m = tiny_model(3);
        let mut c = Counter(Default::default());
        m.forward(&[1, 2, 3], &mut c);
        for name in m.cfg.linear_names() {
            assert!(c.0.contains(&name), "hook missed {name}");
        }
        assert!(c.0.contains("lm_head"));
    }

    #[test]
    fn random_model_every_arch_forwards() {
        for size in ["s", "moe", "nonllama"] {
            let cfg = ModelConfig::by_name(size).unwrap();
            let m = Model::random(cfg, 1);
            let logits = m.forward(&[1, 2, 3, 4], &mut NoHook);
            assert_eq!(logits.len(), 4 * m.cfg.vocab, "{size}");
            assert!(logits.iter().all(|v| v.is_finite()), "{size}");
        }
    }

    #[test]
    fn set_linear_changes_output() {
        let mut m = tiny_model(4);
        let t: Vec<u8> = vec![1, 2, 3, 4];
        let l1 = m.forward(&t, &mut NoHook);
        let zeros = vec![0.0f32; 32 * 32];
        m.set_linear("layers.0.wq", zeros);
        let l2 = m.forward(&t, &mut NoHook);
        let diff: f32 = l1.iter().zip(&l2).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-3);
    }
}
