//! End-to-end server test over localhost TCP: engine + batcher + JSON
//! protocol + metrics, on a synthetic tiny model (no artifacts needed).

use std::sync::Arc;

use quipsharp::model::{Arch, Model, ModelConfig, Params, Tensor};
use quipsharp::serve::{
    serve_blocking, Client, Engine, EngineRequest, NativeEngine, SamplingParams, ServerConfig,
};
use quipsharp::util::rng::Pcg64;

fn make_model(seed: u64) -> Model {
    let cfg = ModelConfig {
        name: "e2e".into(),
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        vocab: 64,
        ctx: 64,
        arch: Arch::Llama,
        n_experts: 2,
    };
    let mut rng = Pcg64::new(seed);
    let mut params = Params::new();
    let d = cfg.d_model;
    let ff = cfg.d_ff;
    let mut dense =
        |m: usize, n: usize, rng: &mut Pcg64| Tensor::new(vec![m, n], rng.gaussian_vec(m * n, 0.1));
    params.insert("embed".into(), dense(cfg.vocab, d, &mut rng));
    params.insert("lm_head".into(), dense(cfg.vocab, d, &mut rng));
    params.insert("final_norm".into(), Tensor::new(vec![d], vec![1.0; d]));
    for i in 0..cfg.n_layers {
        let p = format!("layers.{i}.");
        params.insert(format!("{p}attn_norm"), Tensor::new(vec![d], vec![1.0; d]));
        params.insert(format!("{p}mlp_norm"), Tensor::new(vec![d], vec![1.0; d]));
        for nm in ["wq", "wk", "wv", "wo"] {
            params.insert(format!("{p}{nm}"), dense(d, d, &mut rng));
        }
        params.insert(format!("{p}w_gate"), dense(ff, d, &mut rng));
        params.insert(format!("{p}w_up"), dense(ff, d, &mut rng));
        params.insert(format!("{p}w_down"), dense(d, ff, &mut rng));
    }
    Model::new(cfg, params)
}

#[test]
fn tcp_server_round_trip_with_batching() {
    let model = Arc::new(make_model(1));
    let engine = Arc::new(NativeEngine::start(model.clone(), None, 4));
    let eng_dyn: Arc<dyn Engine> = engine.clone();
    let handle = serve_blocking(eng_dyn, ServerConfig::default()).unwrap();
    let addr = handle.local_addr;

    // Concurrent clients exercise the batcher.
    let mut joins = Vec::new();
    for i in 0..8u8 {
        joins.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            let (tokens, ms) = c.request(&[1, 2, 3 + i % 4], 6).unwrap();
            assert_eq!(tokens.len(), 6);
            assert!(ms >= 0.0);
            tokens
        }));
    }
    let results: Vec<Vec<u8>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    // Same prompt → same deterministic output regardless of batching.
    assert_eq!(results[0], results[4]);

    // Metrics over the wire.
    let mut c = Client::connect(addr).unwrap();
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("requests").as_f64(), Some(8.0));
    assert!(stats.get("tokens").as_f64().unwrap() >= 48.0);

    c.shutdown().unwrap();
    handle.stop();
    engine.stop();
    engine.join();
}

#[test]
fn tcp_prefix_sharing_round_trip() {
    let model = Arc::new(make_model(3));
    let engine = Arc::new(NativeEngine::start(model.clone(), None, 4));
    let eng_dyn: Arc<dyn Engine> = engine.clone();
    let handle = serve_blocking(eng_dyn, ServerConfig::default()).unwrap();
    let addr = handle.local_addr;

    // Register a system prompt over the wire, then serve requests that
    // extend it — once pinning the prefix id explicitly, once relying on
    // the engine's longest-common-prefix auto-detection.
    let sys: Vec<u8> = (0..40).map(|i| ((i * 3 + 2) % 60) as u8).collect();
    let mut c = Client::connect(addr).unwrap();
    assert!(c.register_prefix(1, &sys).unwrap());
    let mut with_suffix = sys.clone();
    with_suffix.push(9);
    let (explicit, _) = c.request_with_prefix(&with_suffix, 6, Some(1)).unwrap();
    let (auto, _) = c.request(&with_suffix, 6).unwrap();
    assert_eq!(explicit.len(), 6);
    // Same prompt, same greedy continuation, shared or not.
    assert_eq!(explicit, auto);

    // The metrics snapshot reports the sharing counters.
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("prefix_hits").as_f64(), Some(2.0));
    // One full prefix page lastingly shared per hit (the partial tail
    // page is cloned back on first write and not counted).
    assert!(stats.get("pages_saved").as_f64().unwrap() >= 2.0);

    c.shutdown().unwrap();
    handle.stop();
    engine.stop();
    engine.join();
}

#[test]
fn tcp_speculative_round_trip() {
    let model = Arc::new(make_model(4));
    let engine = Arc::new(NativeEngine::start(model.clone(), None, 4));
    let eng_dyn: Arc<dyn Engine> = engine.clone();
    let handle = serve_blocking(eng_dyn, ServerConfig::default()).unwrap();
    let addr = handle.local_addr;

    // The "speculate" wire field turns on self-speculative rounds
    // (a dense engine self-drafts); the response must be bit-identical
    // to a plain request for the same prompt.
    let mut c = Client::connect(addr).unwrap();
    let prompt = [7u8, 3, 11];
    let (plain, _) = c.request(&prompt, 8).unwrap();
    let (spec, _) = c.request_speculative(&prompt, 8, 4).unwrap();
    assert_eq!(plain.len(), 8);
    assert_eq!(plain, spec, "speculation changed the served tokens");
    // An explicit 0 opts out and still matches.
    let (off, _) = c.request_speculative(&prompt, 8, 0).unwrap();
    assert_eq!(plain, off);

    // The snapshot reports the draft/accept counters (self-draft:
    // everything drafted was accepted).
    let stats = c.stats().unwrap();
    let drafted = stats.get("tokens_drafted").as_f64().unwrap();
    let accepted = stats.get("tokens_accepted").as_f64().unwrap();
    assert!(drafted > 0.0);
    assert_eq!(drafted, accepted);
    assert_eq!(stats.get("acceptance_rate").as_f64(), Some(1.0));

    c.shutdown().unwrap();
    handle.stop();
    engine.stop();
    engine.join();
}

#[test]
fn tcp_sampled_round_trip() {
    let model = Arc::new(make_model(5));
    let engine = Arc::new(NativeEngine::start(model.clone(), None, 4));
    let eng_dyn: Arc<dyn Engine> = engine.clone();
    let handle = serve_blocking(eng_dyn, ServerConfig::default()).unwrap();
    let addr = handle.local_addr;

    // The temperature/top_k/top_p/seed wire quartet turns on seeded
    // stochastic decode; the stream is a pure function of the request.
    let mut c = Client::connect(addr).unwrap();
    let prompt = [4u8, 9, 17];
    let params = SamplingParams {
        temperature: 0.9,
        top_k: 16,
        top_p: 0.9,
        seed: 20_240_817,
    };
    let (a, _) = c.request_sampled(&prompt, 8, params).unwrap();
    let (b, _) = c.request_sampled(&prompt, 8, params).unwrap();
    assert_eq!(a.len(), 8);
    assert_eq!(a, b, "seeded sampling must reproduce over the wire");
    // A different seed decodes a different stream (fixed seeds, so this
    // either always passes or always fails; a 64-token vocab at this
    // temperature makes an 8-token collision evidence the seed field
    // was dropped, not luck).
    let (other, _) = c
        .request_sampled(&prompt, 8, SamplingParams { seed: 7, ..params })
        .unwrap();
    assert_ne!(a, other, "seed field ignored over the wire");
    // temperature 0 over the wire is greedy: bit-identical to request().
    let (greedy_wire, _) = c
        .request_sampled(
            &prompt,
            8,
            SamplingParams {
                temperature: 0.0,
                ..params
            },
        )
        .unwrap();
    let (greedy, _) = c.request(&prompt, 8).unwrap();
    assert_eq!(greedy_wire, greedy, "temperature 0 must fall through to greedy");

    c.shutdown().unwrap();
    handle.stop();
    engine.stop();
    engine.join();
}

#[test]
fn direct_engine_api_under_load() {
    let model = Arc::new(make_model(2));
    let engine = NativeEngine::start(model.clone(), None, 3);
    let rxs: Vec<_> = (0..10)
        .map(|i| {
            engine.submit(EngineRequest {
                id: i,
                prompt: vec![(i % 60) as u8, 5, 9],
                max_new: 4,
                prefix_id: None,
                speculate_k: None,
                priority: 0,
                sampling: Default::default(),
            })
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(r.id, i as u64);
        assert_eq!(r.tokens.len(), 4);
        assert_eq!(r.prompt_len, 3);
    }
    // Continuous batching actually batched (10 reqs, 3 slots).
    assert!(engine.metrics().mean_batch() > 1.2);
    engine.stop();
    engine.join();
}
