//! Cross-layer bitwise parity of pooled parallel execution against
//! single-threaded execution.
//!
//! Every parallel kernel in the crate shards work so the floating-point
//! op order behind each output element is independent of the thread
//! count: the quantized/dense matmuls give each output row exactly one
//! writer, and the fused attention walk shards whole lanes (a lane's
//! block sequence is never split across workers). These tests pin that
//! contract end to end — the same computation must produce bit-identical
//! results at thread counts {1, 2, 7}; 7 is a deliberately awkward
//! non-power-of-two that exercises uneven chunk splits and the
//! lazy-spawn path past `available_parallelism`.
//!
//! Kernel-level parity lives next to the kernels
//! (`qlinear::tests::decode8_fast_bit_exact_with_chunked`,
//! `paged::tests::fused_attention_bitwise_invariant_across_thread_counts`,
//! `threadpool::tests::helpers_invariant_across_thread_counts`); this
//! file covers the composed paths: a raw quantized matmul, a full
//! `decode_batch_paged` step over forked paged sequences, and a complete
//! speculative draft/verify/rollback round.

use std::collections::BTreeMap;
use std::sync::Arc;

use quipsharp::generation::paged::{pages_per_seq, PagedKv};
use quipsharp::generation::speculative::{spec_round_paged, SpecLane, SpecStats};
use quipsharp::model::qlinear::{E8PTables, QuantMatvec};
use quipsharp::model::{Model, ModelConfig};
use quipsharp::qmodel::{quantize_model, QuantizedModel};
use quipsharp::quant::pipeline::Method;
use quipsharp::util::rng::Pcg64;
use quipsharp::util::threadpool;

/// The swept thread counts. The first entry is the serial reference;
/// each later count must reproduce it bit for bit.
const THREADS: [usize; 3] = [1, 2, 7];

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Synthetic 4-bit (two-stage E8P) model on the small serving config.
/// Identity Hessians: quantization quality is irrelevant to execution
/// parity, and skipping calibration keeps the tests fast.
fn build_qmodel(seed: u64) -> QuantizedModel {
    let model = Model::random(ModelConfig::by_name("s").unwrap(), seed);
    quantize_model(
        &model,
        &BTreeMap::new(),
        &Method::QuipSharp { bits: 4, ft: false },
        7,
    )
    .unwrap()
}

/// A standalone quantized layer with random codes and sign vectors,
/// large enough that the row-tile path dispatches to the pool even at
/// B = 1 (rows × per-row work clears `PAR_MIN_WORK`).
fn random_layer(m: usize, n: usize, seed: u64) -> QuantMatvec {
    let mut rng = Pcg64::new(seed);
    let codes: Vec<u16> = (0..m * n / 8)
        .map(|_| (rng.next_u64() & 0xffff) as u16)
        .collect();
    QuantMatvec {
        m,
        n,
        stage_codes: Arc::new(vec![codes]),
        stage_scales: vec![0.125],
        active_stages: 1,
        su: rng.sign_vec(m),
        sv: rng.sign_vec(n),
        tables: E8PTables::shared(),
    }
}

#[test]
fn quant_matmul_parity_across_thread_counts() {
    let qm = random_layer(512, 256, 3);
    let mut rng = Pcg64::new(5);
    for batch in [1usize, 8] {
        let xs: Vec<f32> = (0..batch * qm.n).map(|_| rng.f32() - 0.5).collect();
        let mut reference: Option<Vec<u32>> = None;
        for &nt in &THREADS {
            let ys = threadpool::with_threads(nt, || {
                let mut ys = vec![0.0f32; batch * qm.m];
                qm.matmul(&xs, batch, &mut ys);
                ys
            });
            match &reference {
                None => reference = Some(bits(&ys)),
                Some(r) => assert_eq!(
                    r,
                    &bits(&ys),
                    "quantized matmul diverged at {nt} threads (B = {batch})"
                ),
            }
        }
    }
}

/// One full serving-layout decode step — batched quantized matmuls plus
/// the fused cross-sequence attention walk over forked paged KVs — must
/// be bit-identical at every thread count.
#[test]
fn decode_batch_paged_parity_across_thread_counts() {
    let qmodel = build_qmodel(11);
    let gen = qmodel.generator();
    let cfg = &gen.model.cfg;
    let bsz = 4usize;
    // Long enough that the attention walk's total rows clear the
    // parallel threshold (2 · rows · d ≥ PAR_MIN_WORK at d = 128).
    let prefix: Vec<u8> = (0..40).map(|i| ((i * 13 + 2) % cfg.vocab) as u8).collect();

    let mut reference: Option<Vec<u32>> = None;
    for &nt in &THREADS {
        let step_logits = threadpool::with_threads(nt, || {
            let mut pool = qmodel.kv_pool((bsz + 1) * pages_per_seq(cfg));
            // A shared prefill forked across lanes, so the step also
            // exercises aliased (copy-on-write) pages.
            let mut parent = PagedKv::new();
            gen.decode_chunk_paged(&prefix, &mut pool, &mut parent);
            let mut kvs: Vec<PagedKv> = (0..bsz)
                .map(|_| {
                    let mut kv = PagedKv::new();
                    kv.fork_prefix(&mut pool, &parent, prefix.len());
                    kv
                })
                .collect();
            let toks: Vec<u8> = (0..bsz).map(|b| ((7 * b + 5) % cfg.vocab) as u8).collect();
            let mut refs: Vec<&mut PagedKv> = kvs.iter_mut().collect();
            let rows = gen.decode_batch_paged(&toks, &mut pool, &mut refs);
            rows.concat()
        });
        match &reference {
            None => reference = Some(bits(&step_logits)),
            Some(r) => assert_eq!(
                r,
                &bits(&step_logits),
                "decode_batch_paged diverged at {nt} threads"
            ),
        }
    }
}

/// A complete speculative round (base-stage draft chunked decode, target
/// chunked verify, paged rollback) over two lanes: the emitted tokens
/// and the carried post-round logits must match bit for bit at every
/// thread count.
#[test]
fn speculative_round_parity_across_thread_counts() {
    let qmodel = build_qmodel(17);
    let target = qmodel.generator();
    let draft = qmodel.draft_generator();
    let cfg = &target.model.cfg;
    let bsz = 2usize;
    let prompt: Vec<u8> = (0..24).map(|i| ((i * 5 + 3) % cfg.vocab) as u8).collect();

    let mut reference: Option<(Vec<Vec<u8>>, Vec<u32>)> = None;
    for &nt in &THREADS {
        let (emitted, logits_bits) = threadpool::with_threads(nt, || {
            let mut pool = qmodel.kv_pool(4 * (bsz + 1) * pages_per_seq(cfg));
            let mut t_kvs = Vec::with_capacity(bsz);
            let mut d_kvs = Vec::with_capacity(bsz);
            let mut logits = Vec::with_capacity(bsz);
            for b in 0..bsz {
                let mut t_kv = PagedKv::new();
                let l = target
                    .decode_chunk_paged(&prompt, &mut pool, &mut t_kv)
                    .pop()
                    .unwrap();
                let mut d_kv = PagedKv::new();
                draft.decode_chunk_paged(&prompt[..prompt.len() - b], &mut pool, &mut d_kv);
                t_kvs.push(t_kv);
                d_kvs.push(d_kv);
                logits.push(l);
            }
            let mut pendings: Vec<Vec<u8>> = (0..bsz)
                .map(|b| prompt[prompt.len() - b..].to_vec())
                .collect();
            let mut stats = SpecStats::default();
            let emitted = {
                let mut lanes: Vec<SpecLane> = t_kvs
                    .iter_mut()
                    .zip(d_kvs.iter_mut())
                    .zip(pendings.iter_mut())
                    .zip(logits.iter_mut())
                    .map(|(((t_kv, d_kv), pending), logits)| SpecLane {
                        k: 3,
                        target_kv: t_kv,
                        draft_kv: d_kv,
                        pending,
                        logits,
                        sampling: Default::default(),
                        pos: prompt.len(),
                    })
                    .collect();
                spec_round_paged(&target, &draft, &mut pool, &mut lanes, &mut stats)
            };
            (emitted, bits(&logits.concat()))
        });
        match &reference {
            None => reference = Some((emitted, logits_bits)),
            Some((re, rl)) => {
                assert_eq!(re, &emitted, "speculative round tokens diverged at {nt} threads");
                assert_eq!(
                    rl, &logits_bits,
                    "speculative round logits diverged at {nt} threads"
                );
            }
        }
    }
}
