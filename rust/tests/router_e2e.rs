//! Fleet end-to-end: N router-fronted engine replicas must serve the
//! exact token streams a single engine serves — under every routing
//! policy, through replica death and re-routing, and with prefix
//! affinity concentrating cache hits.
//!
//! This is the determinism contract of the whole serving fleet: decode
//! is deterministic per request — greedy by construction, sampled via
//! the seeded position-keyed RNG — so no routing, spill, preemption,
//! or re-route decision may ever change tokens. The request mixes
//! interleave greedy and sampled requests, and everything here asserts
//! *bitwise* equality against a single-engine reference, not
//! statistical closeness.

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use quipsharp::model::{Arch, Model, ModelConfig, Params, Tensor};
use quipsharp::serve::{
    Engine, EngineOptions, EngineRequest, NativeEngine, RoutePolicy, Router, RouterOptions,
    SamplingParams,
};
use quipsharp::util::rng::Pcg64;

fn make_model(seed: u64) -> Model {
    let cfg = ModelConfig {
        name: "fleet-e2e".into(),
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        vocab: 64,
        ctx: 64,
        arch: Arch::Llama,
        n_experts: 2,
    };
    let mut rng = Pcg64::new(seed);
    let mut params = Params::new();
    let d = cfg.d_model;
    let ff = cfg.d_ff;
    let mut dense =
        |m: usize, n: usize, rng: &mut Pcg64| Tensor::new(vec![m, n], rng.gaussian_vec(m * n, 0.1));
    params.insert("embed".into(), dense(cfg.vocab, d, &mut rng));
    params.insert("lm_head".into(), dense(cfg.vocab, d, &mut rng));
    params.insert("final_norm".into(), Tensor::new(vec![d], vec![1.0; d]));
    for i in 0..cfg.n_layers {
        let p = format!("layers.{i}.");
        params.insert(format!("{p}attn_norm"), Tensor::new(vec![d], vec![1.0; d]));
        params.insert(format!("{p}mlp_norm"), Tensor::new(vec![d], vec![1.0; d]));
        for nm in ["wq", "wk", "wv", "wo"] {
            params.insert(format!("{p}{nm}"), dense(d, d, &mut rng));
        }
        params.insert(format!("{p}w_gate"), dense(ff, d, &mut rng));
        params.insert(format!("{p}w_up"), dense(ff, d, &mut rng));
        params.insert(format!("{p}w_down"), dense(d, ff, &mut rng));
    }
    Model::new(cfg, params)
}

/// The registered system prefix used across these tests: long enough
/// (40 tokens, more than one 32-row KV page) that both the engine's
/// admission and the router's affinity treat a full match as
/// meaningful.
fn sys_prefix() -> Vec<u8> {
    (0..40).map(|i| ((i * 3 + 2) % 60) as u8).collect()
}

/// A varied request mix: shared-prefix prompts, unique prompts, a
/// spread of SLO classes, and interleaved greedy/sampled decode.
/// Priorities shift who waits, never tokens; seeded sampling is exactly
/// as deterministic per request as greedy — the parity assertion
/// downstream covers both at once.
fn request_mix() -> Vec<EngineRequest> {
    let sys = sys_prefix();
    (0..10u64)
        .map(|i| {
            let prompt = if i < 4 {
                let mut p = sys.clone();
                p.push(100 + i as u8 % 20);
                p
            } else {
                vec![(i % 60) as u8, 5, (3 + i % 7) as u8]
            };
            EngineRequest {
                id: i,
                prompt,
                max_new: 6,
                // Requests 0 and 2 pin the registered prefix explicitly;
                // 1 and 3 rely on auto-detection.
                prefix_id: (i < 4 && i % 2 == 0).then_some(1),
                speculate_k: None,
                priority: ((i % 3) * 3) as u8,
                // Odd ids decode stochastically, each with its own seed
                // and truncation settings.
                sampling: if i % 2 == 1 {
                    SamplingParams {
                        temperature: 0.7 + 0.2 * (i % 3) as f32,
                        top_k: 20,
                        top_p: 0.95,
                        seed: 0xFEED + i,
                    }
                } else {
                    SamplingParams::default()
                },
            }
        })
        .collect()
}

/// Run `reqs` through `engine` and collect id → tokens, asserting every
/// request succeeds.
fn run_all(engine: &dyn Engine, reqs: &[EngineRequest]) -> BTreeMap<u64, Vec<u8>> {
    let rxs: Vec<_> = reqs.iter().map(|r| engine.submit(r.clone())).collect();
    let mut out = BTreeMap::new();
    for (req, rx) in reqs.iter().zip(rxs) {
        let r = rx
            .recv_timeout(Duration::from_secs(120))
            .unwrap_or_else(|e| panic!("request {} never answered: {e:?}", req.id));
        assert!(r.error.is_none(), "request {}: {:?}", req.id, r.error);
        assert_eq!(r.tokens.len(), req.max_new, "request {}", req.id);
        out.insert(r.id, r.tokens);
    }
    out
}

fn fleet(
    model: &Arc<Model>,
    n: usize,
    opts: RouterOptions,
) -> (Vec<Arc<NativeEngine>>, Router) {
    let replicas: Vec<Arc<NativeEngine>> =
        NativeEngine::start_replicas(model.clone(), None, n, EngineOptions::default())
            .into_iter()
            .map(Arc::new)
            .collect();
    let dyns: Vec<Arc<dyn Engine>> = replicas
        .iter()
        .map(|e| e.clone() as Arc<dyn Engine>)
        .collect();
    let router = Router::new(dyns, opts);
    (replicas, router)
}

fn shutdown(replicas: Vec<Arc<NativeEngine>>, router: Router) {
    router.stop();
    drop(router);
    for e in replicas {
        e.join();
    }
}

/// The tentpole pin: the same request mix through 1 reference engine
/// and through N ∈ {2, 4} replicas under every routing policy yields
/// bitwise-identical token streams, and the fleet-merged stats account
/// for every request exactly once.
#[test]
fn fleet_outputs_match_single_engine_under_every_policy() {
    let model = Arc::new(make_model(10));
    let reqs = request_mix();

    let reference = NativeEngine::start(model.clone(), None, 8);
    assert!(reference.register_prefix(1, sys_prefix()));
    let want = run_all(&reference, &reqs);
    reference.stop();
    reference.join();

    for n in [2usize, 4] {
        for policy in [
            RoutePolicy::Prefix,
            RoutePolicy::RoundRobin,
            RoutePolicy::LeastLoaded,
        ] {
            let (replicas, router) = fleet(
                &model,
                n,
                RouterOptions {
                    policy,
                    ..RouterOptions::default()
                },
            );
            assert!(router.register_prefix(1, sys_prefix()));
            let got = run_all(&router, &reqs);
            assert_eq!(
                got,
                want,
                "{n} replicas under {} diverged from the single engine",
                policy.label()
            );
            // Every request completed exactly once fleet-wide: re-routes
            // and spills may move work, never duplicate or drop it.
            let stats = router.stats_json();
            assert_eq!(
                stats.get("requests").as_f64(),
                Some(reqs.len() as f64),
                "{n} replicas under {}",
                policy.label()
            );
            assert_eq!(
                stats.get("replicas_healthy").as_f64(),
                Some(n as f64),
                "healthy fleet reported unhealthy replicas"
            );
            shutdown(replicas, router);
        }
    }
}

/// Fault injection: a replica hard-killed with half the fleet's work in
/// flight is drained, its requests re-route to the survivor, and every
/// caller still receives the exact reference tokens.
#[test]
fn killed_replica_requests_are_rerouted_and_exact() {
    let model = Arc::new(make_model(11));
    // Long decodes keep requests in flight while the kill lands; half
    // the requests sample, so a kill mid-stream also proves a sampled
    // request restarts elsewhere onto the identical token stream.
    let reqs: Vec<EngineRequest> = (0..8u64)
        .map(|i| EngineRequest {
            id: i,
            prompt: vec![(i % 60) as u8, 5, 9],
            max_new: 60,
            prefix_id: None,
            speculate_k: None,
            priority: 0,
            sampling: if i % 2 == 0 {
                SamplingParams {
                    temperature: 1.0,
                    top_k: 0,
                    top_p: 1.0,
                    seed: 0x5EED + i,
                }
            } else {
                SamplingParams::default()
            },
        })
        .collect();

    let reference = NativeEngine::start(model.clone(), None, 8);
    let want = run_all(&reference, &reqs);
    reference.stop();
    reference.join();

    let (replicas, router) = fleet(
        &model,
        2,
        RouterOptions {
            policy: RoutePolicy::LeastLoaded,
            ..RouterOptions::default()
        },
    );
    // Least-loaded alternates over an idle fleet, so both replicas hold
    // in-flight work when replica 0 dies.
    let rxs: Vec<_> = reqs.iter().map(|r| router.submit(r.clone())).collect();
    replicas[0].kill();

    for (req, rx) in reqs.iter().zip(rxs) {
        let r = rx
            .recv_timeout(Duration::from_secs(120))
            .unwrap_or_else(|e| panic!("request {} never answered: {e:?}", req.id));
        assert!(r.error.is_none(), "request {}: {:?}", req.id, r.error);
        assert_eq!(
            r.tokens, want[&req.id],
            "request {} re-routed to different tokens",
            req.id
        );
    }

    let rerouted = router.metrics().requests_rerouted.load(Ordering::Relaxed);
    assert!(rerouted >= 1, "kill mid-flight must re-route something");
    assert_eq!(router.replicas_healthy(), 1);
    let stats = router.stats_json();
    assert_eq!(stats.get("replicas_healthy").as_f64(), Some(1.0));
    assert_eq!(
        stats.get("requests_rerouted").as_f64(),
        Some(rerouted as f64)
    );
    // Each request completed exactly once, all on the survivor.
    assert_eq!(stats.get("requests").as_f64(), Some(reqs.len() as f64));
    shutdown(replicas, router);
}

/// Prefix affinity concentrates one prefix's traffic — and therefore
/// its KV cache — on a single replica: that replica records every
/// `prefix_hits`, the other records none, and tokens still match the
/// reference exactly.
#[test]
fn prefix_affinity_concentrates_hits_on_one_replica() {
    let model = Arc::new(make_model(12));
    let sys = sys_prefix();
    let reqs: Vec<EngineRequest> = (0..6u64)
        .map(|i| {
            let mut prompt = sys.clone();
            prompt.push(100 + i as u8);
            EngineRequest {
                id: i,
                prompt,
                max_new: 5,
                // Mixing explicit pins and auto-detection must land on
                // the same affinity assignment.
                prefix_id: (i % 2 == 0).then_some(1),
                speculate_k: None,
                priority: 0,
                sampling: Default::default(),
            }
        })
        .collect();

    let reference = NativeEngine::start(model.clone(), None, 8);
    assert!(reference.register_prefix(1, sys.clone()));
    let want = run_all(&reference, &reqs);
    reference.stop();
    reference.join();

    let (replicas, router) = fleet(
        &model,
        2,
        RouterOptions {
            policy: RoutePolicy::Prefix,
            spill_margin: 100, // never spill: this test is about affinity
            ..RouterOptions::default()
        },
    );
    assert!(router.register_prefix(1, sys));
    let got = run_all(&router, &reqs);
    assert_eq!(got, want, "affinity routing changed tokens");

    let hits: Vec<u64> = replicas
        .iter()
        .map(|e| e.metrics().prefix_hits.load(Ordering::Relaxed))
        .collect();
    assert!(
        hits.contains(&(reqs.len() as u64)) && hits.contains(&0),
        "prefix hits should concentrate on one replica, got {hits:?}"
    );
    shutdown(replicas, router);
}
