//! Metrics ↔ docs drift guard: the stats field tables in
//! `rust/src/serve/README.md` must match the fields the code actually
//! emits — bidirectionally. A field added to [`Metrics::snapshot`]
//! without a README row fails here, and so does a documented field the
//! snapshot no longer carries. The fleet section is held to the same
//! standard against a real [`Router`]'s merged stats, and the
//! generation-request table against the fields the TCP front-end
//! actually parses (`server::REQUEST_WIRE_FIELDS`) — so a wire field
//! added to the protocol (e.g. the sampling quartet) cannot ship
//! undocumented, and a documented field cannot silently stop parsing.

use std::collections::BTreeSet;
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;

use quipsharp::serve::{
    Engine, EngineRequest, EngineResponse, Metrics, Router, RouterOptions, EVENT_KINDS,
};
use quipsharp::util::json::Json;

fn readme() -> String {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/src/serve/README.md");
    std::fs::read_to_string(path).unwrap_or_else(|e| panic!("read {path}: {e}"))
}

/// Backticked identifiers in the *first* cell of every table row of
/// the section starting at `heading` (rows stop at the next heading).
/// This is the documented field list: one row may name several fields
/// (`` `p50_ms`, `p99_ms` `` share a row).
fn documented_fields(text: &str, heading: &str) -> BTreeSet<String> {
    let start = text
        .lines()
        .position(|l| l.trim() == heading)
        .unwrap_or_else(|| panic!("README section {heading:?} not found"));
    let mut fields = BTreeSet::new();
    for line in text.lines().skip(start + 1) {
        let line = line.trim();
        if line.starts_with('#') {
            break;
        }
        let Some(rest) = line.strip_prefix('|') else {
            continue;
        };
        let Some(first_cell) = rest.split('|').next() else {
            continue;
        };
        // Pull every `identifier` out of the cell; skip the header and
        // separator rows (no backticks there).
        let mut parts = first_cell.split('`');
        while let (Some(_), Some(ident)) = (parts.next(), parts.next()) {
            if !ident.is_empty()
                && ident
                    .chars()
                    .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
            {
                fields.insert(ident.to_string());
            }
        }
    }
    assert!(!fields.is_empty(), "README section {heading:?} lists no fields");
    fields
}

fn json_keys(j: &Json) -> BTreeSet<String> {
    j.as_obj()
        .expect("stats JSON is an object")
        .keys()
        .cloned()
        .collect()
}

fn assert_same(docs: &BTreeSet<String>, code: &BTreeSet<String>, what: &str) {
    let undocumented: Vec<_> = code.difference(docs).collect();
    let stale: Vec<_> = docs.difference(code).collect();
    assert!(
        undocumented.is_empty() && stale.is_empty(),
        "{what} drifted: emitted but undocumented {undocumented:?}, \
         documented but not emitted {stale:?}"
    );
}

#[test]
fn stats_table_matches_snapshot_fields() {
    let docs = documented_fields(&readme(), "### `stats`");
    let code = json_keys(&Metrics::new().snapshot());
    assert_same(&docs, &code, "serve/README.md `stats` table");
}

/// The `phases` block is its own README table: every per-phase
/// `{name}_ms` / `{name}_share` key the snapshot emits must have a row,
/// and vice versa.
#[test]
fn phases_table_matches_snapshot_block() {
    let docs = documented_fields(&readme(), "#### Phases");
    let snapshot = Metrics::new().snapshot();
    let code = json_keys(snapshot.get("phases"));
    assert_same(&docs, &code, "serve/README.md `phases` table");
}

/// Every trace-event kind the tracer can emit must have a README row,
/// and every documented kind must exist in code. [`TraceEvent::kind`]
/// is an exhaustive match over the same enum, so a new variant cannot
/// ship without touching both the wire-name list and this table.
#[test]
fn trace_events_table_matches_event_kinds() {
    let docs = documented_fields(&readme(), "#### Trace events");
    let code: BTreeSet<String> = EVENT_KINDS.iter().map(|s| s.to_string()).collect();
    assert_same(&docs, &code, "serve/README.md trace-events table");
}

#[test]
fn generation_request_table_matches_wire_fields() {
    let docs = documented_fields(&readme(), "## Generation request");
    let code: BTreeSet<String> = quipsharp::serve::server::REQUEST_WIRE_FIELDS
        .iter()
        .map(|s| s.to_string())
        .collect();
    assert_same(&docs, &code, "serve/README.md generation-request table");
}

/// A do-nothing replica so the fleet check runs against the real
/// [`Router::stats_json`] composition, not a hand-maintained list.
struct NullEngine {
    metrics: Arc<Metrics>,
}

impl Engine for NullEngine {
    fn submit(&self, req: EngineRequest) -> Receiver<EngineResponse> {
        let (tx, rx) = channel();
        let _ = tx.send(EngineResponse {
            id: req.id,
            tokens: Vec::new(),
            latency_ms: 0.0,
            prompt_len: req.prompt.len(),
            error: None,
        });
        rx
    }
    fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }
    fn stop(&self) {}
    fn register_prefix(&self, _id: u64, _tokens: Vec<u8>) -> bool {
        true
    }
}

#[test]
fn fleet_stats_table_matches_router_fields() {
    let text = readme();
    let base = documented_fields(&text, "### `stats`");
    let extras = documented_fields(&text, "#### Fleet stats (`--replicas` > 1)");
    let engines: Vec<Arc<dyn Engine>> = (0..2)
        .map(|_| {
            Arc::new(NullEngine {
                metrics: Arc::new(Metrics::new()),
            }) as Arc<dyn Engine>
        })
        .collect();
    let router = Router::new(engines, RouterOptions::default());
    let stats = router.stats_json();

    let documented: BTreeSet<String> = base.union(&extras).cloned().collect();
    assert_same(&documented, &json_keys(&stats), "fleet stats field set");

    // Each per-replica row is a full snapshot plus exactly the three
    // documented annotations.
    let rows = stats.get("replicas").as_arr().expect("replicas array");
    assert_eq!(rows.len(), 2);
    let mut want_row = json_keys(&Metrics::new().snapshot());
    for extra in ["replica", "healthy", "inflight"] {
        want_row.insert(extra.to_string());
    }
    for row in rows {
        assert_same(&want_row, &json_keys(row), "per-replica stats row");
    }
}
