//! Integration tests across the three layers. These need `make artifacts`
//! (corpus + trained weights + AOT HLO); each test skips with a notice if
//! the artifacts are missing so `cargo test` stays green pre-build.

use quipsharp::data::load_corpus;
use quipsharp::eval::perplexity;
use quipsharp::hessian::collect_hessians;
use quipsharp::model::{Model, NoHook};
use quipsharp::qmodel::quantize_model;
use quipsharp::quant::pipeline::Method;
use quipsharp::runtime::{HostTensor, Runtime};
use quipsharp::util::tensorio::TensorFile;

fn art() -> Option<&'static str> {
    if std::path::Path::new("artifacts/model_s.qtz").exists() {
        Some("artifacts")
    } else {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        None
    }
}

#[test]
fn trained_model_beats_uniform() {
    let Some(art) = art() else { return };
    let model = Model::load(art, "s").unwrap();
    let test = load_corpus(art, "corpus_test_w2").unwrap();
    let ppl = perplexity(&model, &test, 128, 4096);
    // Uniform over 256 bytes would be 256; the trained model must be far
    // below (the corpus has ~2 bits/char structure).
    assert!(ppl < 16.0, "trained model ppl {ppl} too high");
    assert!(ppl > 1.0);
}

#[test]
fn quantize_eval_roundtrip_via_tensorfile() {
    let Some(art) = art() else { return };
    let model = Model::load(art, "s").unwrap();
    let calib = load_corpus(art, "corpus_calib").unwrap();
    let hs = collect_hessians(&model, &calib, 4, 128);
    let qm = quantize_model(&model, &hs, &Method::QuipSharp { bits: 2, ft: false }, 1).unwrap();

    // Save packed codes + reload + re-decode must reproduce w_eff.
    let tmp = std::env::temp_dir().join(format!("qtz_roundtrip_{}.qtz", std::process::id()));
    let mut tf = TensorFile::new();
    let (name, ql) = qm.layers.iter().next().unwrap();
    let p = ql.packed.as_ref().unwrap();
    tf.insert(
        "codes",
        quipsharp::util::tensorio::TensorData::from_u16(
            vec![ql.m, ql.n / 8],
            &p.stage_codes[0],
        ),
    );
    tf.save(&tmp).unwrap();
    let tf2 = TensorFile::load(&tmp).unwrap();
    let codes2 = tf2.get("codes").unwrap().to_u16().unwrap();
    assert_eq!(codes2, p.stage_codes[0], "codes roundtrip for {name}");
    std::fs::remove_file(tmp).ok();
}

#[test]
fn e8p_tables_match_python_construction() {
    // aot.py writes the python-built tables; they must equal the rust
    // codebook bit for bit (cross-language contract for the Pallas kernel).
    let Some(art) = art() else { return };
    let path = std::path::Path::new(art).join("e8p_tables.qtz");
    if !path.exists() {
        eprintln!("skipping: {path:?} missing (run `make artifacts`)");
        return;
    }
    let tf = TensorFile::load(path).unwrap();
    let abs_py = tf.f32("abs_table").unwrap();
    let parity_py = tf.get("parity").unwrap().to_i32().unwrap();
    let cb = quipsharp::quant::codebook::e8p::E8P::new();
    let abs_rs = cb.abs_table_f32();
    assert_eq!(abs_py.len(), abs_rs.len());
    for (i, (a, b)) in abs_py.iter().zip(&abs_rs).enumerate() {
        assert_eq!(a, b, "abs table diverges at {i}");
    }
    for (i, (&a, &b)) in parity_py.iter().zip(cb.parity_table().iter()).enumerate() {
        assert_eq!(a, b as i32, "parity diverges at {i}");
    }
}

#[test]
fn pjrt_runtime_runs_kernel_smoke_artifact() {
    let Some(art) = art() else { return };
    if !std::path::Path::new(art).join("manifest.json").exists() {
        eprintln!("skipping: no manifest");
        return;
    }
    let rt = Runtime::new(art).unwrap();
    if !rt.manifest.artifacts.contains_key("e8p_matmul_smoke") {
        eprintln!("skipping: e8p_matmul_smoke not lowered");
        return;
    }
    // Run the Pallas e8p kernel artifact and compare with the rust decoder.
    let m = 64usize;
    let nb = 32usize;
    let n = nb * 8;
    let mut rng = quipsharp::util::rng::Pcg64::new(5);
    let codes: Vec<i32> = (0..m * nb).map(|_| (rng.next_u64() & 0xffff) as i32).collect();
    let x: Vec<f32> = rng.gaussian_vec(4 * n, 1.0);
    let out = rt
        .execute(
            "e8p_matmul_smoke",
            &[
                HostTensor::I32(vec![m, nb], codes.clone()),
                HostTensor::F32(vec![4, n], x.clone()),
            ],
        )
        .unwrap();
    let y = out[0].as_f32().unwrap();
    // Rust-side reference: decode codes then dense matmul.
    let tables = quipsharp::model::qlinear::E8PTables::new();
    let mut w = vec![0.0f32; m * n];
    let mut dec = [0.0f32; 8];
    for r in 0..m {
        for b in 0..nb {
            quipsharp::model::qlinear::decode8(&tables, codes[r * nb + b] as u16, &mut dec);
            w[r * n + b * 8..r * n + b * 8 + 8].copy_from_slice(&dec);
        }
    }
    for bi in 0..4 {
        for r in 0..m {
            let want: f32 = (0..n).map(|j| x[bi * n + j] * w[r * n + j]).sum();
            let got = y[bi * m + r];
            assert!(
                (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                "pjrt e8p kernel mismatch at ({bi},{r}): {got} vs {want}"
            );
        }
    }
}

#[test]
fn pjrt_decode_matches_native_forward() {
    let Some(art) = art() else { return };
    let rt = match Runtime::new(art) {
        Ok(rt) => rt,
        Err(_) => return,
    };
    if !rt.manifest.artifacts.contains_key("s_decode_fp") {
        eprintln!("skipping: s_decode_fp not lowered");
        return;
    }
    let model = Model::load(art, "s").unwrap();
    let eng = quipsharp::serve::pjrt_engine::PjrtBatchEngine::new_fp(&rt, &model, "s_decode_fp")
        .unwrap();
    let prompts: Vec<Vec<u8>> = vec![b"the w".to_vec(), b"ab cd".to_vec()];
    let outs = eng.generate_batch(&prompts, 8).unwrap();
    // Native greedy generation must agree (same argmax path).
    let gen = quipsharp::generation::Generator::dense(&model);
    for (p, o) in prompts.iter().zip(&outs) {
        let native = gen.generate(p, 8);
        assert_eq!(o, &native, "PJRT decode diverged from native for {p:?}");
    }
}

#[test]
fn ppl_ordering_fp_vs_2bit_on_trained_model() {
    let Some(art) = art() else { return };
    let model = Model::load(art, "s").unwrap();
    let calib = load_corpus(art, "corpus_calib").unwrap();
    let hs = collect_hessians(&model, &calib, 8, 256);
    let qm = quantize_model(&model, &hs, &Method::QuipSharp { bits: 2, ft: false }, 1).unwrap();
    let test = load_corpus(art, "corpus_test_w2").unwrap();
    let ppl_fp = perplexity(&model, &test, 128, 2048);
    let ppl_q = perplexity(&qm.model, &test, 128, 2048);
    assert!(ppl_q >= ppl_fp * 0.99, "quantization can't beat fp ({ppl_q} vs {ppl_fp})");
    assert!(ppl_q < ppl_fp * 4.0, "2-bit ppl blowup ({ppl_q} vs {ppl_fp})");
    // And logits stay sane.
    let lg = qm.model.forward(&test[..32], &mut NoHook);
    assert!(lg.iter().all(|v| v.is_finite()));
}

/// Regression test for the large-constant elision bug: jax's
/// `as_hlo_text()` default prints big constants as `constant({...})`,
/// which xla_extension 0.5.1's parser silently corrupts (gathers then
/// return buffer offsets). aot.py must lower with
/// print_large_constants=True; this test catches any regression via the
/// embedded E8P tables.
#[test]
fn e8p_artifact_constants_not_elided() {
    let Some(art) = art() else { return };
    let rt = Runtime::new(art).unwrap();
    if !rt.manifest.artifacts.contains_key("e8p_matmul_smoke") {
        return;
    }
    let text = std::fs::read_to_string("artifacts/e8p_matmul_smoke.hlo.txt").unwrap();
    assert!(
        !text.contains("constant({...})"),
        "HLO text has elided constants — lower with print_large_constants=True"
    );
    let m = 64usize;
    let nb = 32usize;
    let n = nb * 8;
    // All codes = 0 → every 8-block of every row decodes to decode8(0).
    let codes = vec![0i32; m * nb];
    // x = first basis vector.
    let mut x = vec![0.0f32; 4 * n];
    x[0] = 1.0;
    let out = rt
        .execute(
            "e8p_matmul_smoke",
            &[
                HostTensor::I32(vec![m, nb], codes),
                HostTensor::F32(vec![4, n], x),
            ],
        )
        .unwrap();
    let y = out[0].as_f32().unwrap();
    let tables = quipsharp::model::qlinear::E8PTables::new();
    let mut dec = [0.0f32; 8];
    quipsharp::model::qlinear::decode8(&tables, 0, &mut dec);
    eprintln!("rust decode8(0) = {dec:?}");
    eprintln!("pjrt y[0..4] = {:?} (want {} everywhere in col 0..m)", &y[0..4], dec[0]);
    assert!((y[0] - dec[0]).abs() < 1e-4, "got {} want {}", y[0], dec[0]);
}

#[test]
fn pjrt_e8p_decode_matches_native_quantized() {
    // The full three-layer quantized path: rust quantizes, packed codes
    // feed the AOT e8p artifact (L1 Pallas decode inside), generation
    // matches the native fused-decode generator.
    let Some(art) = art() else { return };
    let rt = match Runtime::new(art) {
        Ok(rt) => rt,
        Err(_) => return,
    };
    if !rt.manifest.artifacts.contains_key("s_decode_e8p") {
        eprintln!("skipping: s_decode_e8p not lowered");
        return;
    }
    let model = Model::load(art, "s").unwrap();
    let calib = load_corpus(art, "corpus_calib").unwrap();
    let hs = collect_hessians(&model, &calib, 4, 128);
    let qm = quantize_model(&model, &hs, &Method::QuipSharp { bits: 2, ft: false }, 1).unwrap();
    let eng =
        quipsharp::serve::pjrt_engine::PjrtBatchEngine::new_e8p(&rt, &qm, "s_decode_e8p").unwrap();
    let prompts: Vec<Vec<u8>> = vec![b"the w".to_vec(), b"ab cd".to_vec()];
    let outs = eng.generate_batch(&prompts, 8).unwrap();
    let gen = quipsharp::generation::Generator::quantized(&qm.model, &qm);
    for (p, o) in prompts.iter().zip(&outs) {
        let native = gen.generate(p, 8);
        assert_eq!(o, &native, "PJRT e8p decode diverged from native for {p:?}");
    }
}
