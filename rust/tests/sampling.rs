//! Seeded stochastic decode, pinned end to end.
//!
//! Three contracts from three angles:
//!
//! 1. **Determinism matrix.** The same `(prompt, params, seed)` emits a
//!    bitwise-identical token stream across thread counts {1, 2, 7},
//!    batch sizes {1, 4, 8}, paged vs contiguous KV, fused vs
//!    per-sequence attention, and speculation depths {0, 4} — at the
//!    generator level and through the serving engine's scheduler.
//! 2. **Distribution exactness.** The textbook rejection-sampling rule
//!    (`rejection_sample_round`) driving a Markov chain at draft depths
//!    k ∈ {2, 4, 8} emits transitions distributed exactly as the target
//!    chain — every conditional histogram passes the derived
//!    chi-square / TV bounds at fixed seeds.
//! 3. **Scheduler-event reproducibility.** A pressure-cooked engine that
//!    preempts, spills, and restores sampled sequences — and an fp32
//!    engine that preempts and *restarts* them — must emit the exact
//!    streams an unconstrained engine emits: the position-keyed RNG
//!    re-derives every uniform no matter when or where a position is
//!    decoded.

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use quipsharp::generation::paged::{pages_per_seq, KvPagePool, PagedKv};
use quipsharp::generation::sampling::{draw, next_token, SamplingParams};
use quipsharp::generation::speculative::{rejection_sample_round, Speculator};
use quipsharp::generation::{AttnMode, Generator};
use quipsharp::model::{Arch, Model, ModelConfig};
use quipsharp::qmodel::quantize_model;
use quipsharp::quant::pipeline::Method;
use quipsharp::serve::{Engine, EngineOptions, EngineRequest, NativeEngine};
use quipsharp::util::proptest_lite::assert_histogram_close;
use quipsharp::util::rng::Pcg64;
use quipsharp::util::threadpool;

fn make_model(seed: u64, ctx: usize) -> Model {
    let cfg = ModelConfig {
        name: "sampling-e2e".into(),
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        vocab: 64,
        ctx,
        arch: Arch::Llama,
        n_experts: 2,
    };
    Model::random(cfg, seed)
}

/// Direct sampled decode over *paged* KV — the same absolute-position
/// arithmetic as [`Generator::generate_sampled`], different cache
/// layout.
fn generate_sampled_paged(
    gen: &Generator,
    pool: &mut KvPagePool,
    prompt: &[u8],
    max_new: usize,
    p: &SamplingParams,
) -> Vec<u8> {
    let mut kv = PagedKv::new();
    let mut logits = gen.decode_chunk_paged(prompt, pool, &mut kv).pop().unwrap();
    let mut out = Vec::with_capacity(max_new);
    for _ in 0..max_new {
        if kv.len >= gen.model.cfg.ctx {
            break;
        }
        let t = next_token(&logits, p, prompt.len() + out.len());
        out.push(t);
        logits = gen.decode_batch_paged(&[t], pool, &mut [&mut kv]).pop().unwrap();
    }
    kv.release(pool);
    out
}

/// Generator-level determinism matrix: one reference stream, every
/// decode configuration must reproduce it bitwise.
#[test]
fn sampled_stream_is_bitwise_invariant_across_decode_configs() {
    let model = make_model(41, 128);
    let hs = BTreeMap::new();
    let qm = quantize_model(&model, &hs, &Method::QuipSharp { bits: 4, ft: false }, 3).unwrap();
    let prompt = [5u8, 9, 1, 3];
    let max_new = 12usize;
    let p = SamplingParams {
        temperature: 0.9,
        top_k: 24,
        top_p: 0.95,
        seed: 4242,
    };
    let reference = threadpool::with_threads(1, || qm.generator().generate_sampled(&prompt, max_new, &p));
    assert_eq!(reference.len(), max_new);
    for &nt in &[1usize, 2, 7] {
        threadpool::with_threads(nt, || {
            let target = qm.generator();
            let draft = qm.draft_generator();
            // Contiguous KV, fused attention (the reference config).
            assert_eq!(
                target.generate_sampled(&prompt, max_new, &p),
                reference,
                "contiguous decode diverged at {nt} threads"
            );
            // Per-sequence attention kernel.
            let mut perseq = qm.generator();
            perseq.attn_mode = AttnMode::PerSeq;
            assert_eq!(
                perseq.generate_sampled(&prompt, max_new, &p),
                reference,
                "per-seq attention diverged at {nt} threads"
            );
            // Paged KV.
            let mut pool = qm.kv_pool(2 * pages_per_seq(&model.cfg));
            assert_eq!(
                generate_sampled_paged(&target, &mut pool, &prompt, max_new, &p),
                reference,
                "paged decode diverged at {nt} threads"
            );
            // Speculative decode, off and on.
            for k in [0usize, 4] {
                let spec = Speculator {
                    target: &target,
                    draft: &draft,
                    k,
                    sampling: p,
                };
                let (got, _) = spec.generate(&prompt, max_new);
                assert_eq!(got, reference, "speculation k={k} diverged at {nt} threads");
            }
        });
    }
}

/// Engine-level determinism matrix: a sampled probe request returns the
/// exact direct-decode stream whatever the scheduler is doing around it
/// — batch composition, attention kernel, speculation default, thread
/// count. The engine decodes over paged KV and the reference over
/// contiguous KV, so paged-vs-contiguous rides along for free.
#[test]
fn engine_sampled_stream_is_schedule_invariant() {
    let model = Arc::new(make_model(42, 64));
    let p = SamplingParams {
        temperature: 1.0,
        top_k: 16,
        top_p: 0.9,
        seed: 777,
    };
    let probe_prompt = vec![2u8, 11, 5];
    let max_new = 6usize;
    let reference = Generator::dense(&model).generate_sampled(&probe_prompt, max_new, &p);
    assert_eq!(reference.len(), max_new);

    let run = |opts: EngineOptions, fillers: usize| -> Vec<u8> {
        let eng = NativeEngine::start_with_opts(model.clone(), None, opts);
        let mut rxs = vec![eng.submit(EngineRequest {
            id: 0,
            prompt: probe_prompt.clone(),
            max_new,
            prefix_id: None,
            speculate_k: None,
            priority: 0,
            sampling: p,
        })];
        // Fillers perturb the batch composition around the probe:
        // varied prompts, alternating greedy and (differently seeded)
        // sampled decode.
        for i in 0..fillers as u64 {
            rxs.push(eng.submit(EngineRequest {
                id: i + 1,
                prompt: vec![((7 + i * 5) % 60) as u8, 3, (1 + i % 9) as u8],
                max_new,
                prefix_id: None,
                speculate_k: None,
                priority: (i % 2) as u8,
                sampling: if i % 2 == 0 {
                    SamplingParams::default()
                } else {
                    SamplingParams {
                        seed: 9000 + i,
                        ..p
                    }
                },
            }));
        }
        let mut probe_tokens = Vec::new();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            assert!(resp.error.is_none(), "request {i}: {:?}", resp.error);
            assert_eq!(resp.tokens.len(), max_new);
            if resp.id == 0 {
                probe_tokens = resp.tokens;
            }
        }
        eng.stop();
        eng.join();
        probe_tokens
    };

    for &bsz in &[1usize, 4, 8] {
        for attn_mode in [AttnMode::Fused, AttnMode::PerSeq] {
            for &speculate_k in &[0usize, 4] {
                let got = run(
                    EngineOptions {
                        max_batch: 8,
                        attn_mode,
                        speculate_k,
                        ..EngineOptions::default()
                    },
                    bsz - 1,
                );
                assert_eq!(
                    got, reference,
                    "B={bsz} attn={attn_mode:?} speculate_k={speculate_k} changed the sampled stream"
                );
            }
        }
    }
    for &nt in &[1usize, 2, 7] {
        let got = threadpool::with_threads(nt, || run(EngineOptions::default(), 3));
        assert_eq!(got, reference, "{nt} threads changed the sampled stream");
    }
}

/// The textbook rejection rule driving a Markov chain at k ∈ {2, 4, 8}:
/// by the per-position distribution-exactness identity (pinned by
/// enumeration in the unit tests), *every* emitted transition is
/// distributed as the target chain's conditional — so each state's
/// outgoing-transition histogram must pass the derived chi-square / TV
/// bounds. Seeds are fixed; the bounds hold for all but a ~1e-6 sliver
/// of seeds, so a pass is a pass forever.
#[test]
fn rejection_chain_is_distribution_exact_at_k_2_4_8() {
    let v = 6usize;
    let mut master = Pcg64::new(0xD157);
    let table = |rng: &mut Pcg64| -> Vec<Vec<f64>> {
        (0..v)
            .map(|_| {
                // Floor 0.3 keeps every state's stationary mass large
                // enough that each conditional histogram is well fed.
                let w: Vec<f64> = (0..v).map(|_| rng.range_f64(0.3, 1.0)).collect();
                let s: f64 = w.iter().sum();
                w.into_iter().map(|x| x / s).collect()
            })
            .collect()
    };
    let target: Vec<Vec<f64>> = table(&mut master);
    let draft: Vec<Vec<f64>> = table(&mut master);

    for &k in &[2usize, 4, 8] {
        let mut rng = Pcg64::new_stream(0xCAFE, 2 * k as u64 + 1);
        let mut prev = 0usize;
        let mut counts = vec![vec![0u64; v]; v];
        let mut emitted_total = 0u64;
        while emitted_total < 60_000 {
            // Draft k tokens autoregressively from the draft chain,
            // recording each position's draft and target conditionals
            // along the drafted path (plus the bonus position).
            let mut d_toks = Vec::with_capacity(k);
            let mut d_dists = Vec::with_capacity(k);
            let mut t_dists = Vec::with_capacity(k + 1);
            let mut state = prev;
            for _ in 0..k {
                let dist = draft[state].clone();
                let tok = draw(&dist, rng.f64());
                t_dists.push(target[state].clone());
                d_dists.push(dist);
                d_toks.push(tok as u8);
                state = tok;
            }
            t_dists.push(target[state].clone());
            let out = rejection_sample_round(&t_dists, &d_toks, &d_dists, &mut rng);
            assert!(!out.is_empty() && out.len() <= k + 1);
            for &tok in &out {
                counts[prev][tok as usize] += 1;
                prev = tok as usize;
                emitted_total += 1;
            }
        }
        for s in 0..v {
            assert_histogram_close(&counts[s], &target[s]).unwrap_or_else(|e| {
                panic!("k={k}, transitions out of state {s} are off-target: {e}")
            });
        }
    }
}

/// Scheduler events cannot move a sampled stream: a pool-starved engine
/// that preempts → spills → restores (kv_bits 2) and one that preempts
/// → *restarts* (fp32) must both emit exactly what an unconstrained
/// pool emits, because every re-decoded position re-derives the same
/// uniform from `(seed, position)`.
#[test]
fn sampled_streams_survive_preempt_spill_restore_and_restart() {
    let model = Arc::new(make_model(43, 128));
    let run = |pool_pages: Option<usize>, kv_bits: usize| -> (Vec<Vec<u8>>, u64, u64, u64) {
        let eng = NativeEngine::start_with_opts(
            model.clone(),
            None,
            EngineOptions {
                max_batch: 3,
                pool_pages,
                kv_bits,
                ..EngineOptions::default()
            },
        );
        let mut rxs = Vec::new();
        for i in 0..3u64 {
            rxs.push(eng.submit(EngineRequest {
                id: i,
                prompt: vec![(3 + 5 * i) as u8, (7 + i) as u8],
                max_new: 126,
                prefix_id: None,
                speculate_k: None,
                priority: 0,
                sampling: SamplingParams {
                    temperature: 1.0,
                    top_k: 0,
                    top_p: 1.0,
                    seed: 0xA11CE + i,
                },
            }));
        }
        let outs: Vec<Vec<u8>> = rxs
            .into_iter()
            .map(|rx| {
                let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
                assert!(resp.error.is_none(), "{:?}", resp.error);
                assert_eq!(resp.tokens.len(), 126);
                resp.tokens
            })
            .collect();
        let m = eng.metrics();
        eng.stop();
        eng.join();
        (
            outs,
            m.kv_spills.load(Ordering::Relaxed),
            m.kv_restores.load(Ordering::Relaxed),
            m.preemptions.load(Ordering::Relaxed),
        )
    };

    // Spill/restore path: the 2-bit cold tier parks preempted sampled
    // sequences in the host arena and resumes them mid-stream.
    let (unconstrained, free_spills, _, _) = run(None, 2);
    assert_eq!(free_spills, 0, "worst-case pool must never spill");
    let (constrained, spills, restores, _) = run(Some(5), 2);
    assert!(spills > 0, "a 5-page pool should have forced spills");
    assert!(restores > 0, "spilled sequences must restore");
    assert_eq!(
        constrained, unconstrained,
        "spill/restore changed sampled tokens"
    );

    // Restart path: the fp32 engine re-prefills a preempted sequence
    // from scratch — every regenerated position re-samples identically.
    let (fp32_free, _, _, free_preempts) = run(None, 0);
    assert_eq!(free_preempts, 0);
    let (fp32_tight, _, _, preempts) = run(Some(5), 0);
    assert!(preempts > 0, "a 5-page fp32 pool should have preempted");
    assert_eq!(
        fp32_tight, fp32_free,
        "restart preemption changed sampled tokens"
    );
}
