//! Request-lifecycle traces obey the grammar, end to end.
//!
//! A completed request's fleet-merged trace must read
//!
//! ```text
//! submit → queued → admit → prefill* → decode_round*
//!        → { preempt [→ spill → restore | → queued] , reroute }*
//!        → finish | fail
//! ```
//!
//! with the global sequence stamps strictly increasing, timestamps
//! non-decreasing, `decode_round` totals accounting for every emitted
//! token, and `finish` carrying exactly the decoded total. The checker
//! here is a straight state machine over that grammar; the tests drive
//! it with the nastiest schedules the serving stack produces — pool
//! starvation (spill/restore and fp32 restart preemption), a replica
//! hard-killed mid-stream (re-route), and the JSONL export — plus the
//! `trace` TCP command over a live server. `make trace-smoke` runs the
//! fleet JSONL scenario as the tier-1 smoke.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use quipsharp::model::{Arch, Model, ModelConfig};
use quipsharp::serve::{
    serve_blocking, Client, Engine, EngineOptions, EngineRequest, NativeEngine, RoutePolicy,
    Router, RouterOptions, SamplingParams, ServerConfig, TraceConfig, Tracer,
};
use quipsharp::util::json::Json;

fn make_model(seed: u64, ctx: usize) -> Model {
    let cfg = ModelConfig {
        name: "trace-e2e".into(),
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        vocab: 64,
        ctx,
        arch: Arch::Llama,
        n_experts: 2,
    };
    Model::random(cfg, seed)
}

/// Lifecycle states of the trace grammar. `Preempted` remembers whether
/// the eviction spilled (arena path: `spill → restore` must follow) or
/// restarted (fp32 path: `queued` must follow and the token count
/// resets).
#[derive(Clone, Copy, Debug, PartialEq)]
enum LifeState {
    Start,
    Submitted,
    Queued,
    Admitted,
    Preempted { spilled: bool },
    Spilled,
    Done,
}

/// Validate one completed request's merged trace against the lifecycle
/// grammar: event order, strictly increasing sequence stamps,
/// non-decreasing timestamps, and token accounting (`decode_round`
/// totals are cumulative over the surviving stream; `finish` must carry
/// exactly the decoded total; re-routes and restart preemptions reset
/// the stream).
fn check_completed_trace(trace: &Json) -> Result<(), String> {
    if trace.get("truncated").as_bool() != Some(false) {
        return Err("trace is truncated (ring overwrote its head)".to_string());
    }
    let events = trace
        .get("events")
        .as_arr()
        .ok_or_else(|| "trace has no events array".to_string())?;
    if events.is_empty() {
        return Err("trace has no events".to_string());
    }
    let mut st = LifeState::Start;
    let mut expect = 0usize; // surviving generated-token total
    let mut last_seq = -1.0f64;
    let mut last_t = -1.0f64;
    for (i, e) in events.iter().enumerate() {
        let kind = e
            .get("kind")
            .as_str()
            .ok_or_else(|| format!("event {i} has no kind"))?;
        let seq = e
            .get("seq")
            .as_f64()
            .ok_or_else(|| format!("event {i} has no seq"))?;
        if seq <= last_seq {
            return Err(format!(
                "event {i} (`{kind}`): seq {seq} not after {last_seq}"
            ));
        }
        last_seq = seq;
        let t = e
            .get("t_us")
            .as_f64()
            .ok_or_else(|| format!("event {i} has no t_us"))?;
        if t < last_t {
            return Err(format!("event {i} (`{kind}`): time ran backwards"));
        }
        last_t = t;
        let num = |key: &str| -> Result<usize, String> {
            e.get(key)
                .as_f64()
                .map(|v| v as usize)
                .ok_or_else(|| format!("event {i} (`{kind}`) missing `{key}`"))
        };
        st = match kind {
            "submit" if st == LifeState::Start => LifeState::Submitted,
            "queued" => match st {
                LifeState::Submitted => LifeState::Queued,
                LifeState::Preempted { spilled: false } => {
                    // Restart semantics: the tokens were discarded and
                    // the deterministic decode re-derives the stream.
                    expect = 0;
                    LifeState::Queued
                }
                _ => return Err(format!("event {i}: `queued` illegal in state {st:?}")),
            },
            "admit" if st == LifeState::Queued => {
                if expect != 0 {
                    return Err(format!(
                        "event {i}: admit with {expect} surviving tokens"
                    ));
                }
                LifeState::Admitted
            }
            "prefill" if st == LifeState::Admitted => LifeState::Admitted,
            "decode_round" if st == LifeState::Admitted => {
                let (tokens, total) = (num("tokens")?, num("total")?);
                if total != expect + tokens {
                    return Err(format!(
                        "event {i}: decode_round total {total} != {expect} + {tokens}"
                    ));
                }
                expect = total;
                LifeState::Admitted
            }
            "preempt" if st == LifeState::Admitted => LifeState::Preempted {
                spilled: e
                    .get("spilled")
                    .as_bool()
                    .ok_or_else(|| format!("event {i}: preempt missing `spilled`"))?,
            },
            "spill" if st == (LifeState::Preempted { spilled: true }) => LifeState::Spilled,
            // Restore re-admits with the token stream intact: `expect`
            // survives, and no fresh `admit` follows.
            "restore" if st == LifeState::Spilled => LifeState::Admitted,
            "reroute" if st != LifeState::Done && st != LifeState::Start => {
                // The new replica restarts the stream from scratch.
                expect = 0;
                LifeState::Submitted
            }
            "finish" if st == LifeState::Admitted => {
                let tokens = num("tokens")?;
                if tokens != expect {
                    return Err(format!(
                        "event {i}: finish tokens {tokens} != decoded {expect}"
                    ));
                }
                LifeState::Done
            }
            "fail" if st != LifeState::Done => LifeState::Done,
            _ => return Err(format!("event {i}: `{kind}` illegal in state {st:?}")),
        };
    }
    if st != LifeState::Done {
        return Err(format!("trace ends in non-terminal state {st:?}"));
    }
    Ok(())
}

fn kinds(trace: &Json) -> Vec<String> {
    trace
        .get("events")
        .as_arr()
        .unwrap()
        .iter()
        .map(|e| e.get("kind").as_str().unwrap().to_string())
        .collect()
}

fn ev(seq: u64, kind: &str, extra: Vec<(&'static str, Json)>) -> Json {
    let mut fields = vec![
        ("seq", Json::num(seq as f64)),
        ("t_us", Json::num(seq as f64)),
        ("replica", Json::Null),
        ("kind", Json::str(kind)),
    ];
    fields.extend(extra);
    Json::obj(fields)
}

fn trace_of(events: Vec<Json>) -> Json {
    Json::obj(vec![
        ("id", Json::num(0.0)),
        ("truncated", Json::Bool(false)),
        ("events", Json::Arr(events)),
    ])
}

fn decode(seq: u64, tokens: usize, total: usize) -> Json {
    ev(
        seq,
        "decode_round",
        vec![
            ("tokens", Json::num(tokens as f64)),
            ("total", Json::num(total as f64)),
            ("spec", Json::Bool(false)),
        ],
    )
}

/// The checker itself: accepts the documented lifecycles — including
/// the full spill → restore → reroute gauntlet — and rejects every
/// out-of-grammar mutation.
#[test]
fn grammar_checker_accepts_lifecycles_and_rejects_mutations() {
    let class = |v: f64| vec![("class", Json::num(v))];
    let plain = trace_of(vec![
        ev(0, "submit", class(0.0)),
        ev(1, "queued", class(0.0)),
        ev(2, "admit", vec![("replica_to", Json::num(0.0))]),
        ev(3, "prefill", vec![("tokens", Json::num(3.0))]),
        decode(4, 1, 1),
        ev(5, "finish", vec![("tokens", Json::num(1.0))]),
    ]);
    check_completed_trace(&plain).unwrap();

    // Spill → restore (tokens survive), then a re-route (stream resets).
    let gauntlet = trace_of(vec![
        ev(0, "submit", class(3.0)),
        ev(1, "queued", class(3.0)),
        ev(2, "admit", vec![("replica_to", Json::num(0.0))]),
        ev(3, "prefill", vec![("tokens", Json::num(2.0))]),
        decode(4, 2, 2),
        ev(5, "preempt", vec![("spilled", Json::Bool(true))]),
        ev(6, "spill", vec![("pages", Json::num(2.0))]),
        ev(7, "restore", vec![("pages", Json::num(2.0))]),
        decode(8, 1, 3),
        ev(9, "reroute", vec![("from", Json::num(0.0))]),
        ev(10, "queued", class(3.0)),
        ev(11, "admit", vec![("replica_to", Json::num(1.0))]),
        ev(12, "prefill", vec![("tokens", Json::num(2.0))]),
        decode(13, 3, 3),
        ev(14, "finish", vec![("tokens", Json::num(3.0))]),
    ]);
    check_completed_trace(&gauntlet).unwrap();

    // Restart preemption: no spill, re-queue, token count resets.
    let restart = trace_of(vec![
        ev(0, "submit", class(0.0)),
        ev(1, "queued", class(0.0)),
        ev(2, "admit", vec![("replica_to", Json::num(0.0))]),
        decode(3, 1, 1),
        ev(4, "preempt", vec![("spilled", Json::Bool(false))]),
        ev(5, "queued", class(0.0)),
        ev(6, "admit", vec![("replica_to", Json::num(0.0))]),
        ev(7, "prefill", vec![("tokens", Json::num(2.0))]),
        decode(8, 1, 1),
        ev(9, "finish", vec![("tokens", Json::num(1.0))]),
    ]);
    check_completed_trace(&restart).unwrap();

    let rejects: Vec<(&str, Json)> = vec![
        (
            "decode before admit",
            trace_of(vec![
                ev(0, "submit", class(0.0)),
                ev(1, "queued", class(0.0)),
                decode(2, 1, 1),
            ]),
        ),
        (
            "spill without a spilled preempt",
            trace_of(vec![
                ev(0, "submit", class(0.0)),
                ev(1, "queued", class(0.0)),
                ev(2, "admit", vec![("replica_to", Json::num(0.0))]),
                ev(3, "spill", vec![("pages", Json::num(1.0))]),
            ]),
        ),
        (
            "restore without a spill",
            trace_of(vec![
                ev(0, "submit", class(0.0)),
                ev(1, "queued", class(0.0)),
                ev(2, "admit", vec![("replica_to", Json::num(0.0))]),
                ev(3, "preempt", vec![("spilled", Json::Bool(true))]),
                ev(4, "restore", vec![("pages", Json::num(1.0))]),
            ]),
        ),
        (
            "spill after a restart preempt",
            trace_of(vec![
                ev(0, "submit", class(0.0)),
                ev(1, "queued", class(0.0)),
                ev(2, "admit", vec![("replica_to", Json::num(0.0))]),
                ev(3, "preempt", vec![("spilled", Json::Bool(false))]),
                ev(4, "spill", vec![("pages", Json::num(1.0))]),
            ]),
        ),
        (
            "decode totals that drop a token",
            trace_of(vec![
                ev(0, "submit", class(0.0)),
                ev(1, "queued", class(0.0)),
                ev(2, "admit", vec![("replica_to", Json::num(0.0))]),
                decode(3, 1, 1),
                decode(4, 1, 3),
                ev(5, "finish", vec![("tokens", Json::num(3.0))]),
            ]),
        ),
        (
            "finish claiming more tokens than were decoded",
            trace_of(vec![
                ev(0, "submit", class(0.0)),
                ev(1, "queued", class(0.0)),
                ev(2, "admit", vec![("replica_to", Json::num(0.0))]),
                decode(3, 2, 2),
                ev(4, "finish", vec![("tokens", Json::num(3.0))]),
            ]),
        ),
        (
            "events after the terminal",
            trace_of(vec![
                ev(0, "submit", class(0.0)),
                ev(1, "queued", class(0.0)),
                ev(2, "admit", vec![("replica_to", Json::num(0.0))]),
                decode(3, 1, 1),
                ev(4, "finish", vec![("tokens", Json::num(1.0))]),
                decode(5, 1, 2),
            ]),
        ),
        (
            "a second submit",
            trace_of(vec![
                ev(0, "submit", class(0.0)),
                ev(1, "submit", class(0.0)),
            ]),
        ),
        (
            "sequence stamps out of order",
            trace_of(vec![
                ev(5, "submit", class(0.0)),
                ev(5, "queued", class(0.0)),
            ]),
        ),
        (
            "no terminal event",
            trace_of(vec![
                ev(0, "submit", class(0.0)),
                ev(1, "queued", class(0.0)),
                ev(2, "admit", vec![("replica_to", Json::num(0.0))]),
                decode(3, 1, 1),
            ]),
        ),
    ];
    for (what, t) in rejects {
        assert!(
            check_completed_trace(&t).is_err(),
            "checker accepted {what}"
        );
    }

    // A truncated trace is never a valid completed history.
    let mut t = plain;
    if let Json::Obj(map) = &mut t {
        map.insert("truncated".to_string(), Json::Bool(true));
    }
    assert!(check_completed_trace(&t).is_err());
}

/// Pool starvation with the spill arena on (`kv_bits` 2): preempted
/// sequences spill to the host arena and restore mid-stream, and every
/// completed trace — spill events included — passes the grammar with
/// the full 126-token total on its `finish`.
#[test]
fn spilled_and_restored_requests_trace_contiguously() {
    let model = Arc::new(make_model(43, 128));
    let tracer = Tracer::new(1, TraceConfig::default()).unwrap();
    let eng = NativeEngine::start_with_opts(
        model,
        None,
        EngineOptions {
            max_batch: 3,
            pool_pages: Some(5),
            kv_bits: 2,
            tracer: Some(tracer.writer(0).owning_submit()),
            ..EngineOptions::default()
        },
    );
    let rxs: Vec<_> = (0..3u64)
        .map(|i| {
            eng.submit(EngineRequest {
                id: i,
                prompt: vec![(3 + 5 * i) as u8, (7 + i) as u8],
                max_new: 126,
                prefix_id: None,
                speculate_k: None,
                priority: 0,
                sampling: SamplingParams {
                    temperature: 1.0,
                    top_k: 0,
                    top_p: 1.0,
                    seed: 0xA11CE + i,
                },
            })
        })
        .collect();
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.tokens.len(), 126);
    }
    let m = eng.metrics();
    assert!(
        m.kv_spills.load(Ordering::Relaxed) > 0,
        "a 5-page pool must spill"
    );
    assert!(m.kv_restores.load(Ordering::Relaxed) > 0);

    let mut seen: Vec<String> = Vec::new();
    for id in 0..3u64 {
        let t = eng.trace_json(id);
        check_completed_trace(&t).unwrap_or_else(|e| panic!("request {id}: {e}\n{}", t.emit()));
        let ks = kinds(&t);
        assert_eq!(ks.first().map(String::as_str), Some("submit"), "req {id}");
        assert_eq!(ks.last().map(String::as_str), Some("finish"), "req {id}");
        seen.extend(ks);
    }
    for needed in ["prefill", "decode_round", "preempt", "spill", "restore"] {
        assert!(
            seen.iter().any(|k| k == needed),
            "no `{needed}` event under pool pressure"
        );
    }
    eng.stop();
    eng.join();
}

/// The same starvation without the arena (`kv_bits` 0): preemption
/// restarts — the victim re-queues, re-prefills, and its trace shows
/// the reset (`preempt{spilled:false} → queued → admit`) while still
/// accounting for every surviving token.
#[test]
fn restart_preempted_requests_requeue_and_trace_contiguously() {
    let model = Arc::new(make_model(43, 128));
    let tracer = Tracer::new(1, TraceConfig::default()).unwrap();
    let eng = NativeEngine::start_with_opts(
        model,
        None,
        EngineOptions {
            max_batch: 3,
            pool_pages: Some(5),
            kv_bits: 0,
            tracer: Some(tracer.writer(0).owning_submit()),
            ..EngineOptions::default()
        },
    );
    let rxs: Vec<_> = (0..3u64)
        .map(|i| {
            eng.submit(EngineRequest {
                id: i,
                prompt: vec![(3 + 5 * i) as u8, (7 + i) as u8],
                max_new: 126,
                prefix_id: None,
                speculate_k: None,
                priority: 0,
                sampling: SamplingParams {
                    temperature: 1.0,
                    top_k: 0,
                    top_p: 1.0,
                    seed: 0xA11CE + i,
                },
            })
        })
        .collect();
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.tokens.len(), 126);
    }
    let m = eng.metrics();
    assert!(m.preemptions.load(Ordering::Relaxed) > 0);
    assert_eq!(m.kv_spills.load(Ordering::Relaxed), 0, "fp32 never spills");

    let mut requeued = 0usize;
    for id in 0..3u64 {
        let t = eng.trace_json(id);
        check_completed_trace(&t).unwrap_or_else(|e| panic!("request {id}: {e}\n{}", t.emit()));
        let ks = kinds(&t);
        assert!(!ks.iter().any(|k| k == "spill" || k == "restore"));
        if ks.iter().filter(|k| *k == "queued").count() >= 2 {
            requeued += 1;
        }
    }
    assert!(requeued >= 1, "a preempted request must re-queue");
    eng.stop();
    eng.join();
}

/// A replica hard-killed with work in flight: the router re-routes to
/// the survivor, and the rerouted request's trace is one contiguous
/// history — the dead replica's events, the `reroute`, then the
/// survivor's fresh `queued → admit → … → finish`.
#[test]
fn killed_replica_reroutes_trace_contiguously() {
    let model = Arc::new(make_model(11, 64));
    let tracer = Tracer::new(2, TraceConfig::default()).unwrap();
    let replicas: Vec<Arc<NativeEngine>> = NativeEngine::start_replicas(
        model,
        None,
        2,
        EngineOptions {
            tracer: Some(tracer.writer(0)),
            ..EngineOptions::default()
        },
    )
    .into_iter()
    .map(Arc::new)
    .collect();
    let dyns: Vec<Arc<dyn Engine>> = replicas
        .iter()
        .map(|e| e.clone() as Arc<dyn Engine>)
        .collect();
    let router = Router::new(
        dyns,
        RouterOptions {
            policy: RoutePolicy::LeastLoaded,
            tracer: Some(tracer.front_writer()),
            ..RouterOptions::default()
        },
    );
    let reqs: Vec<EngineRequest> = (0..8u64)
        .map(|i| EngineRequest {
            id: i,
            prompt: vec![(i % 60) as u8, 5, 9],
            max_new: 60,
            prefix_id: None,
            speculate_k: None,
            priority: 0,
            sampling: if i % 2 == 0 {
                SamplingParams {
                    temperature: 1.0,
                    top_k: 0,
                    top_p: 1.0,
                    seed: 0x5EED + i,
                }
            } else {
                SamplingParams::default()
            },
        })
        .collect();
    let rxs: Vec<_> = reqs.iter().map(|r| router.submit(r.clone())).collect();
    replicas[0].kill();
    for (req, rx) in reqs.iter().zip(rxs) {
        let r = rx
            .recv_timeout(Duration::from_secs(120))
            .unwrap_or_else(|e| panic!("request {} never answered: {e:?}", req.id));
        assert!(r.error.is_none(), "request {}: {:?}", req.id, r.error);
    }
    assert!(router.metrics().requests_rerouted.load(Ordering::Relaxed) >= 1);

    let mut rerouted_traces = 0usize;
    for id in 0..8u64 {
        let t = router.trace_json(id);
        check_completed_trace(&t).unwrap_or_else(|e| panic!("request {id}: {e}\n{}", t.emit()));
        if kinds(&t).iter().any(|k| k == "reroute") {
            rerouted_traces += 1;
        }
    }
    assert!(
        rerouted_traces >= 1,
        "kill mid-flight must reroute a traced request"
    );
    router.stop();
    drop(router);
    for e in replicas {
        e.join();
    }
}

/// The tier-1 smoke (`make trace-smoke`): a starved two-replica fleet
/// with a mid-stream kill exports every completed request's merged
/// timeline as one JSONL line, and each line parses and passes the
/// grammar — preempts, spills, restores, and re-routes included.
#[test]
fn trace_smoke_preempted_rerouted_jsonl() {
    let path = std::env::temp_dir().join(format!(
        "quipsharp-trace-smoke-{}.jsonl",
        std::process::id()
    ));
    let model = Arc::new(make_model(7, 128));
    let tracer = Tracer::new(
        2,
        TraceConfig {
            jsonl: Some(path.clone()),
            ..TraceConfig::default()
        },
    )
    .unwrap();
    let replicas: Vec<Arc<NativeEngine>> = NativeEngine::start_replicas(
        model,
        None,
        2,
        EngineOptions {
            max_batch: 3,
            pool_pages: Some(5),
            kv_bits: 2,
            tracer: Some(tracer.writer(0)),
            ..EngineOptions::default()
        },
    )
    .into_iter()
    .map(Arc::new)
    .collect();
    let dyns: Vec<Arc<dyn Engine>> = replicas
        .iter()
        .map(|e| e.clone() as Arc<dyn Engine>)
        .collect();
    let router = Router::new(
        dyns,
        RouterOptions {
            policy: RoutePolicy::LeastLoaded,
            tracer: Some(tracer.front_writer()),
            ..RouterOptions::default()
        },
    );
    let reqs: Vec<EngineRequest> = (0..6u64)
        .map(|i| EngineRequest {
            id: i,
            prompt: vec![((3 + 5 * i) % 60) as u8, ((7 + i) % 60) as u8],
            max_new: 126,
            prefix_id: None,
            speculate_k: None,
            priority: ((i % 3) * 3) as u8,
            sampling: if i % 2 == 0 {
                SamplingParams {
                    temperature: 1.0,
                    top_k: 0,
                    top_p: 1.0,
                    seed: 0xA11CE + i,
                }
            } else {
                SamplingParams::default()
            },
        })
        .collect();
    let rxs: Vec<_> = reqs.iter().map(|r| router.submit(r.clone())).collect();
    replicas[0].kill();
    for (req, rx) in reqs.iter().zip(rxs) {
        let r = rx
            .recv_timeout(Duration::from_secs(300))
            .unwrap_or_else(|e| panic!("request {} never answered: {e:?}", req.id));
        assert!(r.error.is_none(), "request {}: {:?}", req.id, r.error);
        assert_eq!(r.tokens.len(), 126, "request {}", req.id);
    }
    assert!(router.metrics().requests_rerouted.load(Ordering::Relaxed) >= 1);
    let spills: u64 = replicas
        .iter()
        .map(|e| e.metrics().kv_spills.load(Ordering::Relaxed))
        .sum();
    assert!(spills > 0, "a 5-page fleet pool must spill");
    router.stop();
    drop(router);
    for e in replicas {
        e.join();
    }

    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), reqs.len(), "one JSONL line per completion");
    let mut ids: Vec<u64> = Vec::new();
    let mut seen: Vec<String> = Vec::new();
    for line in lines {
        let t = Json::parse(line).unwrap();
        check_completed_trace(&t)
            .unwrap_or_else(|e| panic!("exported trace failed the grammar: {e}\n{line}"));
        ids.push(t.get("id").as_f64().unwrap() as u64);
        seen.extend(kinds(&t));
    }
    ids.sort_unstable();
    assert_eq!(ids, (0..reqs.len() as u64).collect::<Vec<u64>>());
    for needed in ["preempt", "spill", "restore", "reroute", "finish"] {
        assert!(
            seen.iter().any(|k| k == needed),
            "no `{needed}` event in the exported traces"
        );
    }
}

/// The `trace` TCP command: a served request's timeline comes back over
/// the wire, passes the grammar, and an unknown id answers with an
/// empty (not erroneous) timeline.
#[test]
fn trace_command_over_tcp() {
    let model = Arc::new(make_model(5, 64));
    let tracer = Tracer::new(1, TraceConfig::default()).unwrap();
    let engine = Arc::new(NativeEngine::start_with_opts(
        model,
        None,
        EngineOptions {
            max_batch: 4,
            tracer: Some(tracer.writer(0).owning_submit()),
            ..EngineOptions::default()
        },
    ));
    let eng_dyn: Arc<dyn Engine> = engine.clone();
    let handle = serve_blocking(eng_dyn, ServerConfig::default()).unwrap();
    let mut c = Client::connect(handle.local_addr).unwrap();
    let (tokens, _) = c.request(&[1, 2, 3], 6).unwrap();
    assert_eq!(tokens.len(), 6);

    // The server numbers wire requests from 1.
    let t = c.trace(1).unwrap();
    check_completed_trace(&t).unwrap_or_else(|e| panic!("{e}\n{}", t.emit()));
    let ks = kinds(&t);
    assert_eq!(ks.first().map(String::as_str), Some("submit"));
    assert_eq!(ks.last().map(String::as_str), Some("finish"));

    let missing = c.trace(999).unwrap();
    assert!(missing.get("events").as_arr().unwrap().is_empty());
    assert_eq!(missing.get("truncated").as_bool(), Some(false));

    c.shutdown().unwrap();
    handle.stop();
    engine.stop();
    engine.join();
}
