# Tier-1 verification and developer entry points.
#
# `make verify` is the one-command tier-1 gate: release build, tests,
# and formatting. The PJRT path needs the offline xla crate and is off
# by default (see Cargo.toml's `pjrt` feature).

.PHONY: verify build test fmt lint doc bench-batch bench-serve bench-attention \
        bench-attention-smoke bench-spec bench-spec-smoke bench-parallel \
        bench-parallel-smoke bench-kvquant bench-kvquant-smoke \
        bench-router bench-router-smoke trace-smoke tsan-threadpool \
        tsan-paged artifacts

verify:
	cargo build --release
	cargo test -q
	cargo fmt --check

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt

# Lint gate mirrored by the CI `lint` job.
lint:
	cargo fmt --check
	cargo clippy --all-targets -- -D warnings

# Rustdoc must stay buildable with intra-doc links intact (broken links
# are warnings, promoted to errors here). Private items are documented
# too, so module-internal docs (the attention kernels, the scheduler
# internals) stay link-checked. Mirrored by the CI `lint` job.
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --document-private-items

# Batch-sweep generation benchmark; writes BENCH_generation.json.
bench-batch:
	cargo bench --bench bench_generation

# Serving benches: the batch sweep plus the paged-KV pool-pressure sweep
# (admitted sequences, preemptions, tok/s under a half-worst-case pool);
# writes BENCH_generation.json.
bench-serve: bench-batch

# Attention-kernel micro-bench: the cross-sequence fused block walk vs
# the per-sequence baseline, shared-prefix vs unshared, B sweep; writes
# BENCH_attention.json.
bench-attention:
	cargo bench --bench bench_attention

# Seconds-scale smoke run of the same binary (tiny shapes, bit-parity
# checks, no perf assertion). Mirrored by the CI `tier1` job.
bench-attention-smoke:
	cargo bench --bench bench_attention -- --smoke

# Self-speculative decode bench: RVQ base-stage draft + chunked verify
# vs plain batched decode, k × B sweep over a shared-prefix workload;
# writes BENCH_speculative.json (asserts the k=4 sweep beats baseline).
bench-spec:
	cargo bench --bench bench_speculative

# Seconds-scale smoke run: tiny shapes, bitwise spec-vs-plain parity
# checks, no perf assertion. Mirrored by the CI `tier1` job.
bench-spec-smoke:
	cargo bench --bench bench_speculative -- --smoke

# Core-scaling roofline bench for the persistent decode pool: thread
# sweep × batch sweep, tokens/s + weight-stream GB/s vs a pooled memcpy
# roofline; writes BENCH_parallel.json (asserts monotonic 1->4-thread
# scaling at B=8 on full runs unless bandwidth-bound).
bench-parallel:
	cargo bench --bench bench_parallel

# Seconds-scale smoke run: parity preflight + JSON wiring only, no perf
# assertion. Mirrored by the CI `tier1` job.
bench-parallel-smoke:
	cargo bench --bench bench_parallel -- --smoke

# KV-compression A/B: the engine under pool pressure with an fp32 KV
# pool vs the 2-bit E8P cold tier at equal pool bytes. Asserts strictly
# higher sustained concurrency (mean_batch) and prefill_tokens at the
# ideal (spill/restore, no re-prefills); writes BENCH_kvquant.json.
bench-kvquant:
	cargo bench --bench bench_kvquant

# Seconds-scale run of the same A/B with the same assertions (fewer
# requests, shorter decodes). Mirrored by the CI `tier1` job.
bench-kvquant-smoke:
	cargo bench --bench bench_kvquant -- --smoke

# Fleet routing A/B: prefix-affinity vs round-robin (and least-loaded)
# over 2 engine replicas on a shared-prefix workload at equal total pool
# bytes. Asserts bitwise token parity with a single reference engine and
# strictly higher aggregate admitted concurrency for affinity; writes
# BENCH_router.json.
bench-router:
	cargo bench --bench bench_router

# Seconds-scale run of the same A/B with the same assertions (fewer
# requests, shorter decodes). Mirrored by the CI `tier1` job.
bench-router-smoke:
	cargo bench --bench bench_router -- --smoke

# Request-tracing smoke: a starved two-replica fleet with a mid-stream
# kill must export every completed request's trace as JSONL, each line
# passing the lifecycle grammar (preempt/spill/restore/reroute
# included). Seconds-scale; mirrored by the CI `tier1` job.
trace-smoke:
	cargo test -q --test trace_lifecycle trace_smoke_preempted_rerouted_jsonl

# ThreadSanitizer over the worker-pool unit tests (the unsafe dispatch
# path: raw task pointers, SendPtr row handoff, condvar parking).
# Needs nightly + rust-src for -Z build-std; degrades to a skip message
# when no nightly toolchain is installed. Mirrored by the CI `tsan` job.
tsan-threadpool:
	@if rustup toolchain list 2>/dev/null | grep -q nightly; then \
		RUSTFLAGS="-Z sanitizer=thread" cargo +nightly test \
			-Z build-std --target x86_64-unknown-linux-gnu \
			--lib util::threadpool; \
	else \
		echo "tsan-threadpool: no nightly toolchain installed, skipping"; \
	fi

# ThreadSanitizer over the paged-KV/generation tests: the parallel page
# decode inside the fused attention walk (cold-page scratch, shard
# handoff) plus batched decode on the worker pool. Same nightly gating
# as tsan-threadpool. Mirrored by the CI `tsan` job.
tsan-paged:
	@if rustup toolchain list 2>/dev/null | grep -q nightly; then \
		RUSTFLAGS="-Z sanitizer=thread" cargo +nightly test \
			-Z build-std --target x86_64-unknown-linux-gnu \
			--lib generation; \
	else \
		echo "tsan-paged: no nightly toolchain installed, skipping"; \
	fi

# Trained weights + corpus + AOT HLO artifacts (needs the python/JAX
# toolchain; see python/compile/aot.py). Integration tests skip cleanly
# when these are absent.
artifacts:
	python3 python/compile/datagen.py
	python3 python/compile/train.py
	python3 python/compile/aot.py
